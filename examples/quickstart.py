"""Quickstart: build a dynamic spatial index, update it, query it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import porth, queries, spac
from repro.data import points as gen

key = jax.random.PRNGKey(0)
n = 20_000

# ---------------------------------------------------------------- build
pts = gen.uniform(key, n, dim=2)                     # (n, 2) int32
tree = spac.build(pts, phi=32, curve="hilbert",
                  capacity_rows=4 * (n // 32) + 64)
print(f"SPaC-H tree: {int(tree.size)} points, "
      f"{int(tree.num_rows)} leaf rows")

# --------------------------------------------------------- batch update
batch = gen.uniform(jax.random.PRNGKey(1), 2_000, dim=2)
tree = spac.insert(tree, batch)                      # parallel batch insert
tree = spac.delete(tree, pts[:1_000])                # parallel batch delete
assert not bool(tree.overflowed)
print(f"after +2000/-1000: {int(tree.size)} points")

# -------------------------------------------------------------- queries
qpts = gen.uniform(jax.random.PRNGKey(2), 100, dim=2)
d2, ids = queries.knn(tree.view(), qpts, k=10)       # exact batched kNN
nbrs = queries.gather_points(tree.view(), ids)
print(f"10-NN of first query: d2={d2[0, :3]}... -> {nbrs[0, 0]}")

lo = jnp.array([[0, 0]], jnp.int32)
hi = jnp.array([[1 << 18, 1 << 18]], jnp.int32)
cnt, truncated = queries.range_count(tree.view(), lo, hi, max_rows=1024)
print(f"range count in [0, 2^18)^2: {int(cnt[0])} (truncated="
      f"{bool(truncated[0])})")

# ------------------------------------------- P-Orth tree, same interface
t2 = porth.build(pts, jnp.zeros(2, jnp.int32),
                 jnp.full(2, gen.DEFAULT_HI, jnp.int32), phi=32)
t2 = porth.insert(t2, batch)
t2 = porth.delete(t2, pts[:1_000])      # same update sequence as SPaC
d2_p, _ = queries.knn(t2.view(), qpts, k=10)
agree = bool(jnp.allclose(jnp.sort(d2_p, axis=1), jnp.sort(d2, axis=1)))
print("P-Orth agrees with SPaC on kNN distances:", agree)
assert agree
