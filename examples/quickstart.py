"""Quickstart: build a dynamic spatial index, update it, query it.

One facade (`repro.core.make_index`) fronts every tree family in the
paper — P-Orth, the SPaC family, and the kd/Zd baselines — with
automatic capacity management (no `capacity_rows`, no `overflowed`).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import BACKENDS, make_index
from repro.data import points as gen

key = jax.random.PRNGKey(0)
n = 20_000

# ---------------------------------------------------------------- build
pts = gen.uniform(key, n, dim=2)                     # (n, 2) int32
idx = make_index("spac-h", pts, phi=32)              # SPaC over Hilbert
print(f"SPaC-H index: {len(idx)} points in {int(idx.num_rows)} leaf "
      # contract: allow[capacity-internals] display-only introspection;
      # nothing here acts on the capacity
      f"rows ({idx.capacity_rows} allocated)")

# --------------------------------------------------------- batch update
batch = gen.uniform(jax.random.PRNGKey(1), 2_000, dim=2)
idx = idx.insert(batch)              # parallel batch insert (auto-grows)
idx = idx.delete(pts[:1_000])        # parallel batch delete
print(f"after +2000/-1000: {len(idx)} points")

# -------------------------------------------------------------- queries
# Queries are exact by default: the engine sizes its own buffers (no
# max_rows/cap/truncated on this surface) and `impl="auto"` routes each
# kNN to the Pallas brute-force kernel when the index fits a flat scan,
# or to the fused frontier kernel otherwise (on-chip pruned traversal
# with compensated distances — exact at any coordinate magnitude that
# keeps the per-tile spread in the f32 window).
qpts = gen.uniform(jax.random.PRNGKey(2), 100, dim=2)
d2, nbrs, ok = idx.knn_points(qpts, k=10)            # exact batched kNN
print(f"10-NN of first query: d2={d2[0, :3]}... -> {nbrs[0, 0]}")

# forcing an impl pins the route (auto picks by index size). Full
# list: frontier | pallas-frontier | pallas-frontier-interpret | flat
# | pallas | pallas-interpret | ref — see ROADMAP "Query API". Tile
# sizes for the fused kernel are roofline-tuned, not guessed:
#   PYTHONPATH=src python -m benchmarks.roofline --block-sweep --json
d2_fr, _ = idx.knn(qpts, k=10, impl="frontier")      # chunked traversal
d2_fu, _ = idx.knn(qpts, k=10, impl="pallas-frontier")  # fused kernel
d2_bf, _ = idx.knn(qpts, k=10, impl="ref")           # flat scan (jnp)
assert bool(jnp.allclose(d2_fr, d2_bf))              # all exact
assert bool(jnp.allclose(d2_fu, d2_bf))
print("frontier, fused-frontier and brute-force impls agree")

lo = jnp.array([[0, 0]], jnp.int32)
hi = jnp.array([[1 << 18, 1 << 18]], jnp.int32)
cnt = idx.range_count(lo, hi)                        # exact, auto-sized
print(f"range count in [0, 2^18)^2: {int(cnt[0])}")

# ------------------------------------- other backends, same interface
print("registered backends:", ", ".join(sorted(BACKENDS)))
t2 = make_index("porth", pts, phi=32)        # P-Orth tree (paper Sec. 3)
t2 = t2.insert(batch).delete(pts[:1_000])    # same update sequence
d2_p, _ = t2.knn(qpts, k=10)
agree = bool(jnp.allclose(jnp.sort(d2_p, axis=1), jnp.sort(d2, axis=1)))
print("P-Orth agrees with SPaC on kNN distances:", agree)
assert agree

# ------------------------------------------------- observability
# Wrap any section in a span to trace it; counters from the engine
# (plan-cache hits, escalation rounds) land in the same recorder. The
# deferred read pattern keeps dispatch paths sync-free: attach the
# in-flight array, and the value is read once at report()/commit().
from repro import obs

with obs.recording() as rec:
    with obs.span("quickstart.knn", queries=len(qpts)) as sp:
        d2_t, _ = idx.knn(qpts, k=10)
        sp.defer("min_d2", d2_t.min())    # no host sync here
    report = rec.report()                 # <- the barrier: resolves it
print(f"traced knn: {report['spans']['quickstart.knn']['mean']:.2f}ms, "
      f"engine traces so far: {report['counters'].get('engine.trace', 0)}")

trace_path = "/tmp/quickstart_trace.json"
obs.write_chrome_trace(rec, trace_path)
print(f"wrote {trace_path} — inspect with: "
      f"PYTHONPATH=src python -m repro.obs.view {trace_path} "
      f"(or load in Perfetto)")

# ------------------------------------------------- contract linting
# the invariants this example leans on (exact-by-default queries,
# automatic capacity, snapshot-safe serving) are machine-checked; run
#   PYTHONPATH=src python -m repro.analysis.lint src benchmarks examples
# (or `repro-lint` once installed) — see ROADMAP.md "Contracts"

# ------------------------------------------- memory + perf drift
# Index memory is nbytes metadata — shape/dtype arithmetic, no device
# sync — so it is free to print even on dispatch paths.
print(f"index holds {obs.fmt_bytes(idx.nbytes)} across "
      f"{len(idx):,} live points")
# Perf drift vs the committed baseline (results/regress_smoke.json):
#   PYTHONPATH=src python -m repro.obs.regress           # local bands
#   PYTHONPATH=src python -m repro.obs.regress --ci      # CI bands
# (or `repro-regress`); --update rewrites the baseline after an
# intentional perf change, and each run appends results/bench/BENCH_n
