"""Batched LM serving on a reduced config: prefill + greedy decode via
the ServeEngine (the same serve_step the 512-device dry-run lowers at
decode_32k/long_500k shapes).

    PYTHONPATH=src python examples/serve_lm.py [--arch h2o-danube-1.8b]

h2o-danube exercises the sliding-window ring cache; rwkv6-3b the O(1)
recurrent state.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=48)
    ap.add_argument("--new", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch).with_(act_dtype="float32")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_len=args.prompt + args.new)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt), 0, cfg.vocab,
                                 dtype=jnp.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.new)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s incl. compile)")

    # consistency: greedy decode must match the argmax of the full
    # teacher-forced forward over the same prefix at every position
    full = jnp.concatenate([prompts, out], axis=1)
    logits = transformer.forward(params, full, cfg)
    ref = jnp.argmax(logits[:, args.prompt - 1:-1], axis=-1)
    match = float(jnp.mean((ref == out).astype(jnp.float32)))
    print(f"decode-vs-forward greedy agreement: {match:.1%}")
    assert match > 0.99, "serving path diverged from training forward"


if __name__ == "__main__":
    main()
