"""The paper's index sharded over a device mesh, behind the facade.

`make_index(kind, pts, mesh=mesh)` returns a `DistributedIndex` with
the same surface as the local facade: SFC-range partitioning with
sampled splitters, one all_to_all per batch update, fan-out/merge kNN.
Runs here on 8 forced host devices; the identical code drives the
256-chip production mesh (see tests/test_distributed.py and DESIGN.md
Sec. 5).

    PYTHONPATH=src python examples/distributed_index.py
"""

import time

from repro.configs import platform

mesh = platform.simulate_mesh(8)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import make_index  # noqa: E402
from repro.data import points as gen  # noqa: E402


def main():
    n = 16_384
    key = jax.random.PRNGKey(0)
    pts = gen.uniform(key, n, 2)

    t0 = time.time()
    idx = make_index("spac-h", pts, mesh=mesh, phi=32)
    idx.block_until_ready()
    print(f"built over {mesh.shape['data']} shards in "
          f"{time.time() - t0:.2f}s; size={len(idx)}, "
          f"dropped={int(idx.dropped)}")

    batch = gen.uniform(jax.random.PRNGKey(1), 2_048, 2)
    t0 = time.time()
    idx = idx.insert(batch).block_until_ready()
    print(f"all_to_all batch insert of {batch.shape[0]}: "
          f"{time.time() - t0:.2f}s; size={len(idx)}")

    qs = gen.uniform(jax.random.PRNGKey(2), 64, 2)
    d2, nbrs, ok = idx.knn(qs, 10)
    # exactness: compare one query against brute force
    allp = jnp.concatenate([pts, batch]).astype(jnp.float32)
    diff = allp - qs[0].astype(jnp.float32)
    bf = jnp.sort(jnp.sum(diff * diff, -1))[:10]
    assert jnp.allclose(jnp.sort(d2[0]), bf), "distributed kNN mismatch"
    print(f"distributed kNN exact across shards "
          f"(d2[0,0]={float(d2[0, 0]):.1f})")

    lo = jnp.array([[0, 0]], jnp.int32)
    hi = jnp.array([[1 << 19, 1 << 19]], jnp.int32)
    cnt = idx.range_count(lo, hi)   # exact: engine escalates per-shard
    print(f"distributed range count: {int(cnt[0])}")


if __name__ == "__main__":
    main()
