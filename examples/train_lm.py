"""Train a reduced-config LM end-to-end on CPU with the full substrate:
deterministic data pipeline, AdamW + cosine, remat, microbatching,
fault-tolerant loop with async checkpoints, restart-and-resume.

    PYTHONPATH=src python examples/train_lm.py [--arch yi-9b] [--steps 40]

Any of the 10 assigned arch ids works (--arch jamba-1.5-large-398b
trains the reduced hybrid MoE+Mamba variant).
"""

import argparse
import os
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_train_lm")
    # phase 1: train the first 60% of the run with checkpointing
    train_main(["--arch", args.arch, "--smoke",
                "--steps", str(int(args.steps * 0.6)),
                "--batch", str(args.batch), "--seq", str(args.seq),
                "--ckpt-dir", ckpt_dir, "--microbatch", "2"])
    # phase 2: simulate a restart — resume from the checkpoint and finish
    print("-- simulated restart: resuming from checkpoint --")
    train_main(["--arch", args.arch, "--smoke",
                "--steps", str(args.steps),
                "--batch", str(args.batch), "--seq", str(args.seq),
                "--ckpt-dir", ckpt_dir, "--resume", "--microbatch", "2"])


if __name__ == "__main__":
    main()
