"""End-to-end example: the versioned serving runtime under live load.

The paper's target workload — batched updates landing at low latency
while kNN/range queries keep being answered — through
:mod:`repro.serving` instead of the old barrier loop: per epoch the
example (1) snapshots the current version, (2) *dispatches* the epoch's
delete+insert without waiting (versions go in flight on device),
(3) answers a stream of single-query requests against the snapshot via
the :class:`MicroBatcher` (coalesced into pow2-padded batches that hit
the QueryEngine's cached plans, overlapping the in-flight updates), and
(4) ``commit()``s — the only barrier, whose wall time is the update
stall the queries failed to hide.

    PYTHONPATH=src python examples/dynamic_index_serving.py \
        [--n 200000] [--scenario moving-objects] [--kind spac-h]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import points as gen
from repro.serving import LatencyRecorder, MicroBatcher, SpatialServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--scenario", default="uniform",
                    choices=list(gen.SCENARIOS))
    ap.add_argument("--kind", default="spac-h")
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    epochs = args.warmup + args.epochs
    trace = gen.make_trace(args.scenario, n=args.n,
                           batch=args.n // (2 * epochs), steps=epochs)
    t0 = time.time()
    # capacity sized for the trace's peak live points up front, so the
    # serving loop never hits the grow->retry->compact ladder (and the
    # server's deferred overflow check never replays)
    srv = SpatialServer.build(args.kind, trace.bootstrap, phi=32,
                              capacity_points=trace.max_live, window=4)
    jax.block_until_ready(srv.head_index.tree)
    print(f"bootstrap build: {trace.bootstrap.shape[0]} pts "
          f"in {time.time() - t0:.2f}s")

    qk1, qk2 = jax.random.split(jax.random.PRNGKey(9))
    qpts = np.asarray(gen.uniform(qk1, args.queries, 2))
    box_lo, box_hi = map(np.asarray, gen.query_boxes(
        qk2, args.queries, 2, gen.DEFAULT_HI // 64))

    rec = LatencyRecorder()
    batcher = MicroBatcher(max_batch=args.queries, max_delay_s=0.05)
    for e, step in enumerate(trace.steps):
        if e == args.warmup:
            rec.reset()   # drop jit compiles + engine bucket escalation
        snap = srv.snapshot()            # pre-epoch version, isolated
        batcher.target = snap
        with rec.timer("delete", step.delete.shape[0]):
            srv.delete(step.delete)      # async dispatch
        with rec.timer("insert", step.insert.shape[0]):
            srv.insert(step.insert)      # async dispatch
        t1 = time.perf_counter()
        tickets = [batcher.submit_knn(qpts[i], args.k)
                   for i in range(args.queries)]
        jax.block_until_ready([t.result() for t in tickets])
        rec.record("knn", time.perf_counter() - t1, args.queries)
        t1 = time.perf_counter()
        tickets = [batcher.submit_range_count(box_lo[i], box_hi[i])
                   for i in range(args.queries)]
        jax.block_until_ready([t.result() for t in tickets])
        rec.record("range", time.perf_counter() - t1, args.queries)
        with rec.timer("commit"):        # exposed update stall
            srv.commit()

    size = len(srv.head_index)
    print(f"[{args.scenario}/{args.kind}] served {args.epochs} epochs "
          f"(+{args.warmup} warmup), final size {size}, "
          f"head version {srv.head_version}")
    lat = rec.latency_summary()
    for op in ("insert", "delete", "knn", "range", "commit"):
        s = lat[op]
        print(f"  {op:7s}: p50 {s['p50_ms']:8.2f}ms  "
              f"p95 {s['p95_ms']:8.2f}ms  p99 {s['p99_ms']:8.2f}ms")
    thr = rec.throughput(("knn", "range", "insert", "delete"))
    print(f"  sustained: {thr['knn'] + thr['range']:,.0f} q/s, "
          f"{thr['insert'] + thr['delete']:,.0f} update-pts/s "
          f"(wall {rec.wall_s:.2f}s)")

    # correctness spot-check against brute force on the final state
    idx = srv.head_index
    flat_pts, flat_ok = idx.extract_points()
    flat_pts = flat_pts.astype(jnp.float32)
    q = jnp.asarray(qpts[:8], jnp.float32)
    d2, _ = idx.knn(qpts[:8], args.k)
    diff = flat_pts[None] - q[:, None]
    bf = jnp.sort(jnp.where(flat_ok[None], jnp.sum(diff * diff, -1),
                            jnp.inf), axis=1)[:, : args.k]
    assert jnp.allclose(jnp.sort(d2, axis=1), bf), "kNN mismatch!"
    print("  spot-check vs brute force: OK")


if __name__ == "__main__":
    main()
