"""End-to-end driver: a dynamic spatial-index service under live load.

This is the paper's target workload as a service: an index absorbing
batched updates with low latency while serving kNN + range queries —
measured here as sustained update/query throughput over many epochs
(the paper's "incremental" dynamic setting, Sec. 5.1).

    PYTHONPATH=src python examples/dynamic_index_serving.py \
        [--n 200000] [--dist varden]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import queries as Q
from repro.core import spac
from repro.data import points as gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--dist", default="uniform",
                    choices=list(gen.GENERATORS))
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    n = args.n
    m = n // (2 * args.epochs)
    key = jax.random.PRNGKey(0)
    stream = gen.GENERATORS[args.dist](key, n, 2)
    qk1, qk2 = jax.random.split(jax.random.PRNGKey(9))
    ind_q = gen.GENERATORS[args.dist](qk1, args.queries, 2)
    box_lo, box_hi = gen.query_boxes(qk2, args.queries, 2,
                                     gen.DEFAULT_HI // 64)

    t0 = time.time()
    tree = spac.build(stream[: n // 2], phi=32,
                      capacity_rows=4 * (n // 32) + 64)
    jax.block_until_ready(tree.pts)
    print(f"bootstrap build: {n // 2} pts in {time.time() - t0:.2f}s")

    ins_t = del_t = knn_t = rng_t = 0.0
    n_knn = n_rng = 0
    for e in range(args.epochs):
        batch = stream[n // 2 + e * m: n // 2 + (e + 1) * m]
        if batch.shape[0] < m:
            break
        t0 = time.time()
        tree = spac.insert(tree, batch)
        jax.block_until_ready(tree.pts)
        ins_t += time.time() - t0
        assert not bool(tree.overflowed), "resize needed: grow+compact"

        t0 = time.time()
        d2, ids = Q.knn(tree.view(), ind_q, args.k)
        jax.block_until_ready(d2)
        knn_t += time.time() - t0
        n_knn += args.queries

        t0 = time.time()
        cnt, trunc = Q.range_count(tree.view(), box_lo, box_hi, 1024)
        jax.block_until_ready(cnt)
        rng_t += time.time() - t0
        n_rng += args.queries

        # churn: retire a quarter of this batch
        t0 = time.time()
        tree = spac.delete(tree, batch[: m // 4])
        jax.block_until_ready(tree.pts)
        del_t += time.time() - t0

    size = int(tree.size)
    print(f"[{args.dist}] served {args.epochs} epochs, final size {size}")
    print(f"  insert: {ins_t:6.2f}s  ({args.epochs * m / ins_t:>12,.0f}"
          f" pts/s)")
    print(f"  delete: {del_t:6.2f}s  ({args.epochs * m / 4 / del_t:>12,.0f}"
          f" pts/s)")
    print(f"  kNN   : {knn_t:6.2f}s  ({n_knn / knn_t:>12,.0f} q/s)")
    print(f"  range : {rng_t:6.2f}s  ({n_rng / rng_t:>12,.0f} q/s)")

    # correctness spot-check against brute force on the final state
    view = tree.view()
    flat_ok = (view.valid & view.active[:, None]).reshape(-1)
    flat_pts = view.pts.reshape(-1, 2).astype(jnp.float32)
    q = ind_q[:8].astype(jnp.float32)
    d2, _ = Q.knn(view, ind_q[:8], args.k)
    diff = flat_pts[None] - q[:, None]
    bf = jnp.sort(jnp.where(flat_ok[None], jnp.sum(diff * diff, -1),
                            jnp.inf), axis=1)[:, : args.k]
    assert jnp.allclose(jnp.sort(d2, axis=1), bf), "kNN mismatch!"
    print("  spot-check vs brute force: OK")


if __name__ == "__main__":
    main()
