"""End-to-end driver: a dynamic spatial-index service under live load.

This is the paper's target workload as a service: an index absorbing
batched updates with low latency while serving kNN + range queries —
measured here as sustained update/query throughput over many epochs
(the paper's "incremental" dynamic setting, Sec. 5.1).

The service runs on the `SpatialIndex` facade in serving mode:
`donate=True` releases the old tree's buffers into each update, the
jit-cached update closures guarantee the fixed-shape hot path never
retraces, and capacity management is automatic (an overflow triggers
the facade's grow -> retry -> compact ladder instead of an assert).

    PYTHONPATH=src python examples/dynamic_index_serving.py \
        [--n 200000] [--dist varden] [--kind spac-h]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import make_index
from repro.data import points as gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--dist", default="uniform",
                    choices=list(gen.GENERATORS))
    ap.add_argument("--kind", default="spac-h")
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    n = args.n
    m = n // (2 * args.epochs)
    key = jax.random.PRNGKey(0)
    stream = gen.GENERATORS[args.dist](key, n, 2)
    qk1, qk2 = jax.random.split(jax.random.PRNGKey(9))
    ind_q = gen.GENERATORS[args.dist](qk1, args.queries, 2)
    box_lo, box_hi = gen.query_boxes(qk2, args.queries, 2,
                                     gen.DEFAULT_HI // 64)

    t0 = time.time()
    # capacity_points sizes rows for the lifetime maximum up front;
    # donate=True hands the old tree's buffers to each update step
    idx = make_index(args.kind, stream[: n // 2], phi=32,
                     capacity_points=n, donate=True)
    idx.block_until_ready()
    print(f"bootstrap build: {n // 2} pts in {time.time() - t0:.2f}s")

    ins_t = del_t = knn_t = rng_t = 0.0
    n_knn = n_rng = 0
    for e in range(args.epochs):
        batch = stream[n // 2 + e * m: n // 2 + (e + 1) * m]
        if batch.shape[0] < m:
            break
        t0 = time.time()
        idx = idx.insert(batch).block_until_ready()
        ins_t += time.time() - t0

        t0 = time.time()
        d2, ids = idx.knn(ind_q, args.k)
        jax.block_until_ready(d2)
        knn_t += time.time() - t0
        n_knn += args.queries

        t0 = time.time()
        cnt = idx.range_count(box_lo, box_hi)   # exact: engine-sized
        jax.block_until_ready(cnt)
        rng_t += time.time() - t0
        n_rng += args.queries

        # churn: retire a quarter of this batch
        t0 = time.time()
        idx = idx.delete(batch[: m // 4]).block_until_ready()
        del_t += time.time() - t0

    size = len(idx)
    print(f"[{args.dist}/{args.kind}] served {args.epochs} epochs, "
          f"final size {size}")
    print(f"  insert: {ins_t:6.2f}s  ({args.epochs * m / ins_t:>12,.0f}"
          f" pts/s)")
    print(f"  delete: {del_t:6.2f}s  ({args.epochs * m / 4 / del_t:>12,.0f}"
          f" pts/s)")
    print(f"  kNN   : {knn_t:6.2f}s  ({n_knn / knn_t:>12,.0f} q/s)")
    print(f"  range : {rng_t:6.2f}s  ({n_rng / rng_t:>12,.0f} q/s)")

    # correctness spot-check against brute force on the final state
    flat_pts, flat_ok = idx.extract_points()
    flat_pts = flat_pts.astype(jnp.float32)
    q = ind_q[:8].astype(jnp.float32)
    d2, _ = idx.knn(ind_q[:8], args.k)
    diff = flat_pts[None] - q[:, None]
    bf = jnp.sort(jnp.where(flat_ok[None], jnp.sum(diff * diff, -1),
                            jnp.inf), axis=1)[:, : args.k]
    assert jnp.allclose(jnp.sort(d2, axis=1), bf), "kNN mismatch!"
    print("  spot-check vs brute force: OK")


if __name__ == "__main__":
    main()
