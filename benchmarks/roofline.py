"""Roofline report: renders EXPERIMENTS.md §Roofline tables from the
dry-run JSONL records (results/dryrun_*.jsonl).

Each row: per-device compute/memory/collective seconds, dominant term,
MODEL_FLOPS/HLO_FLOPS (useful fraction), resident state GiB, and the
step-time lower bound max(terms) -> roofline fraction.

Run:  PYTHONPATH=src python -m benchmarks.roofline results/*.jsonl
"""

from __future__ import annotations

import argparse
import glob
import json


def load(paths):
    recs = {}
    for path in paths:
        for line in open(path):
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r   # last wins
    return recs


def table(recs, mesh="16x16"):
    rows = []
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'collect_s':>10s} {'dominant':>10s} {'useful':>7s}"
           f" {'state GiB':>9s} {'bound_s':>10s}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if not r.get("ok"):
            rows.append(f"{arch:26s} {shape:12s} FAILED: "
                        f"{r.get('error', '?')[:60]}")
            continue
        t = r["terms"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        state = r["memory"].get("argument_size_in_bytes", 0) / 2**30
        rows.append(
            f"{arch:26s} {shape:12s} {t['compute_s']:10.3e}"
            f" {t['memory_s']:10.3e} {t['collective_s']:10.3e}"
            f" {t['bottleneck'][:-2]:>10s} {r['useful_frac']:7.1%}"
            f" {state:9.2f} {bound:10.3e}")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*",
                    default=sorted(glob.glob("results/dryrun_*.jsonl")))
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    if not args.paths:
        print("no dry-run records found — run repro.launch.dryrun first")
        return
    recs = load(args.paths)
    print(f"== roofline (per-device, mesh {args.mesh}) ==")
    print(table(recs, args.mesh))
    n_ok = sum(1 for r in recs.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(recs)} cells ok")


if __name__ == "__main__":
    main()
