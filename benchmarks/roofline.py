"""Roofline report: the spatial-kernel sweep (achieved FLOPs/bytes per
kernel) plus the EXPERIMENTS.md §Roofline tables from the LM dry-run
JSONL records (results/dryrun_*.jsonl).

Spatial sweep (``--spatial`` / ``--json``): per (backend, kernel) —
kNN, range-count, batch insert — time the facade call the figure
benchmarks time (same sizes as fig4/fig5/fig10), divide an analytic
useful-work model (flops, minimum bytes moved) by the measured wall
time, and report achieved GFLOP/s, GB/s and arithmetic intensity. The
sweep runs under a ``repro.obs`` recorder: the model/achieved numbers
are emitted as obs counters/gauges (``roofline.<kind>.<kernel>.*``)
and the recorder's report — including the engine's own plan-cache and
trace counters from the very same calls — lands in the ``--json``
payload (baseline: ``results/roofline.json``).

LM table: each row is per-device compute/memory/collective seconds,
dominant term, MODEL_FLOPS/HLO_FLOPS (useful fraction), resident state
GiB, and the step-time lower bound max(terms) -> roofline fraction.

Run:  PYTHONPATH=src python -m benchmarks.roofline --spatial --n 20000
      PYTHONPATH=src python -m benchmarks.roofline --json   # results/
      PYTHONPATH=src python -m benchmarks.roofline results/*.jsonl
"""

from __future__ import annotations

import argparse
import functools
import glob
import json
import math

from repro import obs
from repro.data import points as gen

from . import common

SPATIAL_KINDS = ("porth", "spac-h")
DEFAULT_JSON = "results/roofline.json"


# -- spatial-kernel roofline ------------------------------------------------

def kernel_models(n: int, nq: int, k: int, dim: int, batch: int) -> dict:
    """Analytic useful-work models: (flops, minimum bytes moved) per
    kernel at float32. Deliberately *useful* work — a brute-force
    distance matrix for kNN, one compare pass for range-count, a
    resort-merge for insert — so achieved/peak reads as the price of
    the index structure, mirroring MODEL_FLOPS/HLO_FLOPS in the LM
    table."""
    f32 = 4
    return {
        # nq*n distances (sub, mul, add per dim) + running k-min compare
        "knn": (nq * n * (3 * dim + 1),
                f32 * (n * dim + nq * dim + 2 * nq * k)),
        # two bound compares per dim per (box, point) + the reduction
        "range_count": (nq * n * (2 * dim + 1),
                        f32 * (n * dim + 2 * nq * dim + nq)),
        # merge a sorted batch into the sorted live set: compare-bound
        "insert": ((n + batch) * max(1.0, math.log2(n + batch)),
                   f32 * dim * (2 * n + 2 * batch)),
    }


def spatial_sweep(kinds=SPATIAL_KINDS, n: int = 20_000, nq: int = 256,
                  k: int = 10, dist: str = "uniform", box_frac: int = 64,
                  batch_ratio: float = 0.01, phi: int = 32,
                  verbose: bool = True) -> dict:
    """Time the fig4/fig5/fig10-shaped kernels per backend and attach
    achieved-vs-model roofline numbers; returns the json-able payload
    (including the obs report recorded over the sweep)."""
    import jax

    dim = 2
    batch = max(int(n * batch_ratio), 64)
    pts = common.points_for(dist, n)
    ind_q, _ = common.knn_queries(dist, nq)
    lo, hi = gen.query_boxes(jax.random.PRNGKey(9), nq, dim,
                             gen.DEFAULT_HI // box_frac)
    ins = common.points_for(dist, batch, seed=3)
    models = kernel_models(n, nq, k, dim, batch)
    models["knn_chunked"] = models["knn"]   # same useful work, old route
    results: dict = {}
    # capture_costs: each new query/update plan is AOT-compiled once
    # (during common.timed's warmup call) and its while-loop-aware HLO
    # flops/bytes land as plan.cost.* counters, so every cell can carry
    # compiled-plan cost next to the analytic model (achieved-vs-model
    # per plan, not just per formula)
    with obs.recording(obs.Recorder(capture_costs=True)) as rec_obs:
        for kind in kinds:
            idx = common.build_index(kind, pts, phi=phi,
                                     capacity_points=n + batch)
            timers = {
                # auto routes to the fused frontier kernel at this size;
                # knn_chunked pins the host-orchestrated traversal so
                # the baseline keeps before/after side by side
                "knn": lambda: common.timed(idx.knn, ind_q, k),
                "knn_chunked": lambda: common.timed(
                    functools.partial(idx.knn, impl="frontier"),
                    ind_q, k),
                "range_count": lambda: common.timed(idx.range_count,
                                                    lo, hi),
                "insert": lambda: common.timed(idx.insert, ins),
            }
            sig_prefix = {"knn": "knn.", "knn_chunked": "knn.",
                          "range_count": "range_count.",
                          "insert": f"update.{kind}.insert."}
            row: dict = {}
            for kern, run_timed in timers.items():
                seen = set(obs.costs.plan_costs(rec_obs.counters))
                t, _ = run_timed()
                flops, byts = models[kern]
                cell = {
                    "time_s": t,
                    "model_flops": flops,
                    "model_bytes": byts,
                    "achieved_gflops_s": flops / t / 1e9,
                    "achieved_gbytes_s": byts / t / 1e9,
                    "intensity_flop_per_byte": flops / byts,
                }
                # compiled-plan cost captured by this kernel's calls;
                # escalation can compile several plans — the max-bytes
                # one is the converged plan that dominates steady state
                captured = {
                    s: c for s, c in
                    obs.costs.plan_costs(rec_obs.counters).items()
                    if s not in seen and s.startswith(sig_prefix[kern])}
                if captured:
                    top = max(captured,
                              key=lambda s: captured[s].get("bytes", 0))
                    hlo_bytes = captured[top].get("bytes", 0)
                    cell["plan_sig"] = top
                    cell["plan_hlo_bytes"] = hlo_bytes
                    cell["plan_xla_flops"] = captured[top].get(
                        "xla_flops", 0)
                    # >1: XLA's compiled program moves more bytes than
                    # the useful-work minimum — the structure's price
                    cell["hlo_vs_model_bytes"] = \
                        hlo_bytes / byts if byts else 0.0
                row[kern] = cell
                base = f"roofline.{kind}.{kern}"
                obs.count(f"{base}.model_flops", flops)
                obs.count(f"{base}.model_bytes", byts)
                obs.gauge(f"{base}.gflops_s", cell["achieved_gflops_s"])
                obs.gauge(f"{base}.gbytes_s", cell["achieved_gbytes_s"])
            results[kind] = row
            if verbose:
                cells = " ".join(
                    f"{kern} {row[kern]['time_s'] * 1e3:8.2f}ms "
                    f"{row[kern]['achieved_gflops_s']:6.2f}GF/s"
                    for kern in timers)
                print(f"{kind:10s} {cells}", flush=True)
        report = rec_obs.report()
    return {"config": {"n": n, "nq": nq, "k": k, "dim": dim,
                       "dist": dist, "batch": batch, "phi": phi},
            "kinds": list(kinds), "results": results, "obs": report}


FRONTIER_BLOCK_QS = (8, 16, 32, 64)
FRONTIER_BLOCK_PS = (128, 256, 512, 1024)


@functools.lru_cache(maxsize=None)
def _frontier_cell(k: int, block_q: int, block_p: int):
    """One jitted fused-frontier closure per tile cell (sweep helper)."""
    import jax

    from repro.kernels.frontier.ops import knn_frontier_impl
    return jax.jit(functools.partial(knn_frontier_impl, k=k,
                                     block_q=block_q, block_p=block_p))


def block_sweep(kinds=SPATIAL_KINDS, n: int = 20_000, nq: int = 64,
                k: int = 10, dist: str = "uniform", phi: int = 32,
                block_qs=FRONTIER_BLOCK_QS, block_ps=FRONTIER_BLOCK_PS,
                verbose: bool = True) -> dict:
    """Tile sweep for the fused frontier kernel: time every
    (block_q, block_p) cell at the serve-smoke query shape, record
    achieved GB/s per cell as obs gauges
    (``roofline.block_sweep.<kind>.bq<q>.bp<p>.gbytes_s``) and emit the
    chosen defaults (min total time across backends) — the numbers
    behind ``kernels/frontier/tuning.py``, so future kernel PRs tune
    from data instead of constants."""
    pts = common.points_for(dist, n)
    q, _ = common.knn_queries(dist, nq)
    flops, byts = kernel_models(n, nq, k, 2, 64)["knn"]
    cells: dict = {}
    totals: dict = {}
    with obs.recording() as rec_obs:
        for kind in kinds:
            idx = common.build_index(kind, pts, phi=phi)
            v = idx.view()
            args = (v.pts, v.valid, v.active, v.bbox_lo, v.bbox_hi, q)
            for bq in block_qs:
                for bp in block_ps:
                    fn = _frontier_cell(k, bq, bp)
                    t, _ = common.timed(fn, *args)
                    gbs = byts / t / 1e9
                    cells[f"{kind}.bq{bq}.bp{bp}"] = {
                        "time_s": t, "achieved_gbytes_s": gbs}
                    totals[(bq, bp)] = totals.get((bq, bp), 0.0) + t
                    obs.gauge(f"roofline.block_sweep.{kind}"
                              f".bq{bq}.bp{bp}.gbytes_s", gbs)
            if verbose:
                best_kind = min(
                    ((c["time_s"], key) for key, c in cells.items()
                     if key.startswith(f"{kind}.")))
                print(f"{kind:10s} best tile {best_kind[1]} "
                      f"{best_kind[0] * 1e3:.2f}ms", flush=True)
        report = rec_obs.report()
    bq, bp = min(totals, key=totals.get)
    chosen = {"block_q": bq, "block_p": bp,
              "rule": "min total time across kinds"}
    if verbose:
        print(f"chosen defaults: block_q={bq} block_p={bp} "
              f"(kernels/frontier/tuning.py)", flush=True)
    return {"config": {"n": n, "nq": nq, "k": k, "dist": dist,
                       "phi": phi, "kinds": list(kinds)},
            "cells": cells, "chosen": chosen, "obs": report}


def spatial_table(payload: dict) -> str:
    hdr = (f"{'index':10s} {'kernel':12s} {'time_ms':>9s} "
           f"{'GFLOP/s':>9s} {'GB/s':>8s} {'F/B':>7s}")
    rows = [hdr, "-" * len(hdr)]
    for kind, row in payload["results"].items():
        for kern, c in row.items():
            rows.append(
                f"{kind:10s} {kern:12s} {c['time_s'] * 1e3:9.2f} "
                f"{c['achieved_gflops_s']:9.2f} "
                f"{c['achieved_gbytes_s']:8.2f} "
                f"{c['intensity_flop_per_byte']:7.1f}")
    return "\n".join(rows)


# -- LM dry-run tables ------------------------------------------------------

def load(paths):
    recs = {}
    for path in paths:
        for line in open(path):
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r   # last wins
    return recs


def table(recs, mesh="16x16"):
    rows = []
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'collect_s':>10s} {'dominant':>10s} {'useful':>7s}"
           f" {'state GiB':>9s} {'bound_s':>10s}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if not r.get("ok"):
            rows.append(f"{arch:26s} {shape:12s} FAILED: "
                        f"{r.get('error', '?')[:60]}")
            continue
        t = r["terms"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        state = r["memory"].get("argument_size_in_bytes", 0) / 2**30
        rows.append(
            f"{arch:26s} {shape:12s} {t['compute_s']:10.3e}"
            f" {t['memory_s']:10.3e} {t['collective_s']:10.3e}"
            f" {t['bottleneck'][:-2]:>10s} {r['useful_frac']:7.1%}"
            f" {state:9.2f} {bound:10.3e}")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="dry-run jsonl records for the LM table "
                    "(default: results/dryrun_*.jsonl)")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--spatial", action="store_true",
                    help="run the spatial-kernel roofline sweep")
    ap.add_argument("--kinds", default=",".join(SPATIAL_KINDS))
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--nq", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--dist", default="uniform")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON, default=None,
                    metavar="PATH", help="run the spatial sweep and "
                    f"write its baseline (default {DEFAULT_JSON})")
    ap.add_argument("--block-sweep", action="store_true",
                    help="sweep fused-frontier (block_q, block_p) tiles "
                    "at the serve-smoke query shape; lands in the --json "
                    "payload under 'block_sweep'")
    args = ap.parse_args()
    if args.spatial or args.json or args.block_sweep:
        payload = None
        if args.spatial or args.json:
            print(f"== spatial-kernel roofline (n={args.n}, "
                  f"nq={args.nq}, k={args.k}, {args.dist}) ==")
            payload = spatial_sweep(kinds=tuple(args.kinds.split(",")),
                                    n=args.n, nq=args.nq, k=args.k,
                                    dist=args.dist)
            print(spatial_table(payload))
        if args.block_sweep:
            print(f"== fused-frontier tile sweep (n={args.n}, nq=64, "
                  f"k={args.k}, {args.dist}) ==")
            bs = block_sweep(kinds=tuple(args.kinds.split(",")),
                             n=args.n, k=args.k, dist=args.dist)
            payload = payload or {}
            payload["block_sweep"] = bs
        if args.json:
            common.write_json(args.json, payload,
                              "spatial-kernel roofline baseline")
        return
    paths = args.paths or sorted(glob.glob("results/dryrun_*.jsonl"))
    if not paths:
        print("no dry-run records found — run repro.launch.dryrun "
              "first, or pass --spatial for the spatial-kernel sweep")
        return
    recs = load(paths)
    print(f"== roofline (per-device, mesh {args.mesh}) ==")
    print(table(recs, args.mesh))
    n_ok = sum(1 for r in recs.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(recs)} cells ok")


if __name__ == "__main__":
    main()
