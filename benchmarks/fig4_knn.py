"""Paper Fig. 4: k-NN time vs k (1, 10, 100), InD + OOD, after
incremental insertion — validates that query cost grows sub-linearly
with k and the Hilbert/Morton and space-partitioning/R-tree orderings
hold across k.

``--json`` additionally sweeps the engine's forced impls (frontier
traversal vs flat brute-force scan) and records q/s per
(backend, impl) under ``results/`` — the bench trajectory baseline.

Run:  PYTHONPATH=src python -m benchmarks.fig4_knn --n 50000
      PYTHONPATH=src python -m benchmarks.fig4_knn --n 20000 --json
"""

from __future__ import annotations

import argparse

from . import common

KS = (1, 10, 100)
IMPLS = ("auto", "frontier", "flat")


def run(n=50_000, nq=500, dist="varden", indexes=None, phi=32,
        batch_ratio=0.01, verbose=True, impls=("auto",)):
    names = indexes or ["porth", "spac-h", "spac-z", "kd", "zd"]
    pts = common.points_for(dist, n)
    ind_q, ood_q = common.knn_queries(dist, nq)
    out = {}
    m = max(int(n * batch_ratio), 64)
    for name in names:
        idx = common.build_index(name, pts[: n // 2], phi=phi,
                                 capacity_points=n)
        steps = (n // 2) // m
        for b in range(steps):
            idx = idx.insert(pts[n // 2 + b * m: n // 2 + (b + 1) * m])
        rec = {}
        for impl in impls:
            tag = "" if impl == "auto" else f"{impl}_"
            for k in KS:
                rec[f"{tag}ind_k{k}"], _ = common.timed(
                    idx.knn, ind_q, k, impl=impl)
                rec[f"{tag}ood_k{k}"], _ = common.timed(
                    idx.knn, ood_q, k, impl=impl)
        out[name] = rec
        if verbose:
            print(common.fmt_row(name, [rec[f"ind_k{k}"] for k in KS]
                                 + [rec[f"ood_k{k}"] for k in KS]),
                  flush=True)
    return out


def qps_records(out, nq: int, impls=IMPLS):
    """Flatten run() output to q/s per (backend, impl, k, workload)."""
    recs = {}
    for name, rec in out.items():
        for impl in impls:
            tag = "" if impl == "auto" else f"{impl}_"
            recs.setdefault(name, {})[impl] = {
                f"{side}_k{k}": nq / rec[f"{tag}{side}_k{k}"]
                for side in ("ind", "ood") for k in KS
                if rec.get(f"{tag}{side}_k{k}")}
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--nq", type=int, default=500)
    ap.add_argument("--dist", default="varden")
    ap.add_argument("--json", nargs="?", const="results/fig4_knn.json",
                    default=None, metavar="PATH",
                    help="also sweep forced impls and write q/s per "
                         "(backend, impl) as json")
    args = ap.parse_args()
    impls = IMPLS if args.json else ("auto",)
    print(common.fmt_row("index", [f"InD k={k}" for k in KS]
                         + [f"OOD k={k}" for k in KS]))
    out = run(n=args.n, nq=args.nq, dist=args.dist, impls=impls)
    if args.json:
        common.write_json(args.json,
                          dict(n=args.n, nq=args.nq, dist=args.dist,
                               qps=qps_records(out, args.nq, impls)),
                          "q/s per (backend, impl)")


if __name__ == "__main__":
    main()
