"""Paper Fig. 4: k-NN time vs k (1, 10, 100), InD + OOD, after
incremental insertion — validates that query cost grows sub-linearly
with k and the Hilbert/Morton and space-partitioning/R-tree orderings
hold across k.

Run:  PYTHONPATH=src python -m benchmarks.fig4_knn --n 50000
"""

from __future__ import annotations

import argparse

from . import common

KS = (1, 10, 100)


def run(n=50_000, nq=500, dist="varden", indexes=None, phi=32,
        batch_ratio=0.01, verbose=True):
    names = indexes or ["porth", "spac-h", "spac-z", "kd", "zd"]
    pts = common.points_for(dist, n)
    ind_q, ood_q = common.knn_queries(dist, nq)
    out = {}
    m = max(int(n * batch_ratio), 64)
    for name in names:
        idx = common.build_index(name, pts[: n // 2], phi=phi,
                                 capacity_points=n)
        steps = (n // 2) // m
        for b in range(steps):
            idx = idx.insert(pts[n // 2 + b * m: n // 2 + (b + 1) * m])
        rec = {}
        for k in KS:
            rec[f"ind_k{k}"], _ = common.timed(idx.knn, ind_q, k)
            rec[f"ood_k{k}"], _ = common.timed(idx.knn, ood_q, k)
        out[name] = rec
        if verbose:
            print(common.fmt_row(name, [rec[f"ind_k{k}"] for k in KS]
                                 + [rec[f"ood_k{k}"] for k in KS]),
                  flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--nq", type=int, default=500)
    ap.add_argument("--dist", default="varden")
    args = ap.parse_args()
    print(common.fmt_row("index", [f"InD k={k}" for k in KS]
                         + [f"OOD k={k}" for k in KS]))
    run(n=args.n, nq=args.nq, dist=args.dist)


if __name__ == "__main__":
    main()
