"""Paper Fig. 5: range-list time vs output size.

Claim validated: for large ranges, emitting the result list dominates
and the gap between index families shrinks (range queries are less
index-sensitive than kNN).

Run:  PYTHONPATH=src python -m benchmarks.fig5_range --n 50000
"""

from __future__ import annotations

import argparse

import jax

from repro.data.points import query_boxes

from . import common

SIDES = (2**10, 2**12, 2**14)    # of a 2^20 domain


def run(n=50_000, nq=200, dist="uniform", indexes=None, phi=32,
        verbose=True):
    names = indexes or ["porth", "spac-h", "spac-z", "kd", "zd"]
    pts = common.points_for(dist, n)
    out = {}
    for name in names:
        idx = common.build_index(name, pts, phi=phi, capacity_points=n)
        rec = {}
        for side in SIDES:
            lo, hi = query_boxes(jax.random.PRNGKey(side), nq, 2, side)
            # exact by construction: the engine auto-sizes its buffers
            # (pre-engine this script hand-capped the output and
            # silently dropped hits past it — results could be short)
            t, (ids, cnt) = common.timed(idx.range_list, lo, hi)
            rec[f"side_{side}"] = t
            rec[f"out_{side}"] = float(cnt.mean())
        out[name] = rec
        if verbose:
            print(common.fmt_row(
                name, [rec[f"side_{s}"] for s in SIDES]
                + [rec[f"out_{s}"] for s in SIDES]), flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--nq", type=int, default=200)
    ap.add_argument("--dist", default="uniform")
    args = ap.parse_args()
    print(common.fmt_row("index", [f"t side={s}" for s in SIDES]
                         + [f"avg out s={s}" for s in SIDES]))
    run(n=args.n, nq=args.nq, dist=args.dist)


if __name__ == "__main__":
    main()
