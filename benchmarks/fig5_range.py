"""Paper Fig. 5: range-list time vs output size.

Claim validated: for large ranges, emitting the result list dominates
and the gap between index families shrinks (range queries are less
index-sensitive than kNN).

``--json`` records q/s and mean output size per (backend, box side)
under ``results/`` — mirrors ``fig4_knn.py --json``, the bench
trajectory baseline.

Run:  PYTHONPATH=src python -m benchmarks.fig5_range --n 50000
      PYTHONPATH=src python -m benchmarks.fig5_range --n 20000 --json
"""

from __future__ import annotations

import argparse

import jax

from repro.data.points import query_boxes

from . import common

SIDES = (2**10, 2**12, 2**14)    # of a 2^20 domain


def run(n=50_000, nq=200, dist="uniform", indexes=None, phi=32,
        verbose=True):
    names = indexes or ["porth", "spac-h", "spac-z", "kd", "zd"]
    pts = common.points_for(dist, n)
    out = {}
    for name in names:
        idx = common.build_index(name, pts, phi=phi, capacity_points=n)
        rec = {}
        for side in SIDES:
            lo, hi = query_boxes(jax.random.PRNGKey(side), nq, 2, side)
            # exact by construction: the engine auto-sizes its buffers
            # (pre-engine this script hand-capped the output and
            # silently dropped hits past it — results could be short)
            t, (ids, cnt) = common.timed(idx.range_list, lo, hi)
            rec[f"side_{side}"] = t
            rec[f"out_{side}"] = float(cnt.mean())
        out[name] = rec
        if verbose:
            print(common.fmt_row(
                name, [rec[f"side_{s}"] for s in SIDES]
                + [rec[f"out_{s}"] for s in SIDES]), flush=True)
    return out


def qps_records(out, nq: int):
    """Flatten run() output to q/s + mean output size per (backend,
    side) — the fig4_knn.py --json shape."""
    return {name: {f"side_{s}": {"qps": nq / rec[f"side_{s}"],
                                 "avg_out": rec[f"out_{s}"]}
                   for s in SIDES}
            for name, rec in out.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--nq", type=int, default=200)
    ap.add_argument("--dist", default="uniform")
    ap.add_argument("--json", nargs="?", const="results/fig5_range.json",
                    default=None, metavar="PATH",
                    help="write q/s + avg output per (backend, side)")
    args = ap.parse_args()
    print(common.fmt_row("index", [f"t side={s}" for s in SIDES]
                         + [f"avg out s={s}" for s in SIDES]))
    out = run(n=args.n, nq=args.nq, dist=args.dist)
    if args.json:
        common.write_json(args.json,
                          dict(n=args.n, nq=args.nq, dist=args.dist,
                               qps=qps_records(out, args.nq)),
                          "q/s per (backend, side)")


if __name__ == "__main__":
    main()
