"""Paper Fig. 9 (appendix E): 3D synthetic datasets.

Same grid as Fig. 3 but dim=3 (octree splits for P-Orth, 10-bit/dim
Morton/Hilbert codes for SPaC). Validates that the SFC-based SPaC is
least sensitive to dimensionality.

Run:  PYTHONPATH=src python -m benchmarks.fig9_3d --n 30000
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.core import porth, queries as Q, spac

from . import common

HI3 = 1 << 20


def make_indexes_3d(phi=32, total_cap=None):
    lo = jnp.zeros((3,), jnp.int32)
    hi = jnp.full((3,), HI3, jnp.int32)

    def cap(n):
        return 4 * ((total_cap or n) // phi + 1) + 64

    return {
        "porth": dict(
            build=lambda p: porth.build(p, lo, hi, phi=phi, lam=2,
                                        capacity_rows=cap(len(p))),
            insert=porth.insert, delete=porth.delete,
            view=lambda t: t.view()),
        "spac-h": dict(
            build=lambda p: spac.build(p, phi=phi, curve="hilbert",
                                       bits=10, coord_bits=20,
                                       capacity_rows=cap(len(p))),
            insert=spac.insert, delete=spac.delete,
            view=lambda t: t.view()),
        "spac-z": dict(
            build=lambda p: spac.build(p, phi=phi, curve="morton",
                                       bits=10, coord_bits=20,
                                       capacity_rows=cap(len(p))),
            insert=spac.insert, delete=spac.delete,
            view=lambda t: t.view()),
    }


def run(n=30_000, nq=300, verbose=True):
    out = {}
    for dist in ("uniform", "varden"):
        pts = common.points_for(dist, n, dim=3)
        ind_q, _ = common.knn_queries(dist, nq, dim=3)
        for name, ix in make_indexes_3d(total_cap=n).items():
            rec = {}
            rec["build"], tree = common.timed(ix["build"], pts)
            m = max(n // 100, 64)
            rec["ins"], tree = common.timed(ix["insert"], tree,
                                            pts[:m])
            rec["del"], tree = common.timed(ix["delete"], tree, pts[:m])
            rec["knn"], _ = common.timed(Q.knn, ix["view"](tree), ind_q,
                                         10)
            out[(dist, name)] = rec
            if verbose:
                print(common.fmt_row(f"{dist[:6]}/{name}",
                                     [rec["build"], rec["ins"],
                                      rec["del"], rec["knn"]]),
                      flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    args = ap.parse_args()
    print(common.fmt_row("dist/index", ["build", "ins 1%", "del 1%",
                                        "knn10"]))
    run(n=args.n)


if __name__ == "__main__":
    main()
