"""Paper Fig. 9 (appendix E): 3D synthetic datasets.

Same grid as Fig. 3 but dim=3 (octree splits for P-Orth, 10-bit/dim
Morton/Hilbert codes for SPaC). Validates that the SFC-based SPaC is
least sensitive to dimensionality.

Run:  PYTHONPATH=src python -m benchmarks.fig9_3d --n 30000
"""

from __future__ import annotations

import argparse

from . import common

# per-kind params for the 3D regime: 10-bit/dim SFC codes on a 2^20
# domain; porth derives lam=2 (octree, 2 levels/round) from dim itself
KINDS_3D = {
    "porth": dict(),
    "spac-h": dict(bits=10, coord_bits=20),
    "spac-z": dict(bits=10, coord_bits=20),
}


def run(n=30_000, nq=300, phi=32, verbose=True):
    out = {}
    for dist in ("uniform", "varden"):
        pts = common.points_for(dist, n, dim=3)
        ind_q, _ = common.knn_queries(dist, nq, dim=3)
        for name, params in KINDS_3D.items():
            rec = {}
            rec["build"], idx = common.timed(
                common.build_index, name, pts, phi=phi,
                capacity_points=n, **params)
            m = max(n // 100, 64)
            rec["ins"], idx = common.timed(idx.insert, pts[:m])
            rec["del"], idx = common.timed(idx.delete, pts[:m])
            rec["knn"], _ = common.timed(idx.knn, ind_q, 10)
            out[(dist, name)] = rec
            if verbose:
                print(common.fmt_row(f"{dist[:6]}/{name}",
                                     [rec["build"], rec["ins"],
                                      rec["del"], rec["knn"]]),
                      flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    args = ap.parse_args()
    print(common.fmt_row("dist/index", ["build", "ins 1%", "del 1%",
                                        "knn10"]))
    run(n=args.n)


if __name__ == "__main__":
    main()
