"""Shared benchmark harness: registry-driven index construction + timing.

Index construction goes through :func:`repro.core.make_index` — the same
facade every example and test uses — so each figure script is a loop over
``BENCH_KINDS`` x distributions with no per-family adapter code. Queries
go through the facade's :class:`repro.core.engine.QueryEngine`, so
timed results are exact by construction (no hand-sized ``max_rows``/
``cap``, no silently-truncated answers); ``timed``'s warmup pass also
lets the engine converge its buffer buckets so escalation re-runs never
land inside a timed rep. CPU wall-times here are *relative* evidence
(the paper's absolute numbers come from a 112-core Xeon); the claims we
validate are ratios — e.g. SPaC vs the total-order CPAM baseline,
P-Orth vs the Zd-style presort — which are hardware-portable because
both sides run the same JAX/XLA substrate.
"""

from __future__ import annotations

import functools
import time

import jax

from repro.core import make_index
from repro.data import points as gen

HI = gen.DEFAULT_HI

# every registered backend the figure grids sweep (cpam-* are the
# total-order ablation; spac-m is a spac-z alias and would be redundant)
BENCH_KINDS = ("porth", "spac-h", "spac-z", "cpam-h", "cpam-z", "zd", "kd")


def build_index(kind: str, pts, *, phi: int = 32,
                capacity_points: int | None = None, **params):
    """Build one benchmark index; capacity sized for the max points ever
    present (``capacity_points``) by the facade's shared heuristic."""
    return make_index(kind, pts, phi=phi, capacity_points=capacity_points,
                      **params)


def timed(fn, *args, warmup: int = 1, reps: int = 3, **kw):
    """Median wall time with block_until_ready (jit-compile excluded)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2], out


def timed_once(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    return time.perf_counter() - t0, out


@functools.lru_cache(maxsize=None)
def _cached_points(dist: str, n: int, seed: int, dim: int = 2):
    return gen.GENERATORS[dist](jax.random.PRNGKey(seed), n, dim)


def points_for(dist: str, n: int, seed: int = 0, dim: int = 2):
    return _cached_points(dist, n, seed, dim)


def knn_queries(dist: str, nq: int, seed: int = 9, dim: int = 2):
    """InD queries: drawn from the same distribution; OOD: uniform."""
    ind = gen.GENERATORS[dist](jax.random.PRNGKey(seed), nq, dim)
    ood = gen.uniform(jax.random.PRNGKey(seed + 1), nq, dim)
    return ind, ood


def write_json(path: str, payload: dict, what: str) -> None:
    """One baseline-writing recipe for every ``--json`` flag, so the
    committed ``results/*.json`` files share a stable shape."""
    import json
    import os
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {what} -> {path}")


def fmt_row(name, cells, w=9):
    return name.ljust(10) + " ".join(
        (f"{c:{w}.3f}" if isinstance(c, float) else str(c).rjust(w))
        for c in cells)
