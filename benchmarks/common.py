"""Shared benchmark harness: index adapters + timing.

Every index exposes build/insert/delete/view behind one dict so each
figure script is a loop over INDEXES x distributions. CPU wall-times
here are *relative* evidence (the paper's absolute numbers come from a
112-core Xeon); the claims we validate are ratios — e.g. SPaC vs the
total-order CPAM baseline, P-Orth vs the Zd-style presort — which are
hardware-portable because both sides run the same JAX/XLA substrate.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core import baselines, porth, queries, spac
from repro.data import points as gen

HI = gen.DEFAULT_HI
ROOT_LO = jnp.zeros((2,), jnp.int32)
ROOT_HI = jnp.full((2,), HI, jnp.int32)


def _cap(n, phi):
    return 4 * ((n + phi - 1) // phi) + 64


def make_indexes(phi: int = 32, total_cap: int | None = None):
    """total_cap: row capacity sized for the *max* points ever present."""
    def cap(n):
        return _cap(total_cap or n, phi)

    return {
        "porth": dict(
            build=lambda p: porth.build(
                p, ROOT_LO, ROOT_HI, phi=phi, capacity_rows=cap(len(p))),
            insert=lambda t, p: porth.insert(t, p),
            delete=lambda t, p: porth.delete(t, p),
            view=lambda t: t.view()),
        "spac-h": dict(
            build=lambda p: spac.build(
                p, phi=phi, curve="hilbert", capacity_rows=cap(len(p))),
            insert=lambda t, p: spac.insert(t, p),
            delete=lambda t, p: spac.delete(t, p),
            view=lambda t: t.view()),
        "spac-z": dict(
            build=lambda p: spac.build(
                p, phi=phi, curve="morton", capacity_rows=cap(len(p))),
            insert=lambda t, p: spac.insert(t, p),
            delete=lambda t, p: spac.delete(t, p),
            view=lambda t: t.view()),
        "cpam-h": dict(   # total-order ablation: sorts every touched row
            build=lambda p: spac.build(
                p, phi=phi, curve="hilbert", capacity_rows=cap(len(p))),
            insert=lambda t, p: spac.insert(t, p, sort_rows=True),
            delete=lambda t, p: spac.delete(t, p),
            view=lambda t: t.view()),
        "cpam-z": dict(
            build=lambda p: spac.build(
                p, phi=phi, curve="morton", capacity_rows=cap(len(p))),
            insert=lambda t, p: spac.insert(t, p, sort_rows=True),
            delete=lambda t, p: spac.delete(t, p),
            view=lambda t: t.view()),
        "zd": dict(
            build=lambda p: baselines.zd_build(
                p, phi=phi, capacity_rows=cap(len(p))),
            insert=lambda t, p: baselines.zd_insert(
                t, p, capacity_rows=t.pts.shape[0]),
            delete=lambda t, p: baselines.zd_delete(
                t, p, capacity_rows=t.pts.shape[0]),
            view=lambda t: t.view()),
        "kd": dict(
            build=lambda p: baselines.kd_build(
                p, phi=phi, capacity_rows=cap(len(p))),
            insert=lambda t, p: baselines.kd_insert(
                t, p, capacity_rows=t.pts.shape[0]),
            delete=lambda t, p: baselines.kd_delete(
                t, p, capacity_rows=t.pts.shape[0]),
            view=lambda t: t.view()),
    }


def timed(fn, *args, warmup: int = 1, reps: int = 3, **kw):
    """Median wall time with block_until_ready (jit-compile excluded)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2], out


def timed_once(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    return time.perf_counter() - t0, out


@functools.lru_cache(maxsize=None)
def _cached_points(dist: str, n: int, seed: int, dim: int = 2):
    return gen.GENERATORS[dist](jax.random.PRNGKey(seed), n, dim)


def points_for(dist: str, n: int, seed: int = 0, dim: int = 2):
    return _cached_points(dist, n, seed, dim)


def knn_queries(dist: str, nq: int, seed: int = 9, dim: int = 2):
    """InD queries: drawn from the same distribution; OOD: uniform."""
    ind = gen.GENERATORS[dist](jax.random.PRNGKey(seed), nq, dim)
    ood = gen.uniform(jax.random.PRNGKey(seed + 1), nq, dim)
    return ind, ood


def fmt_row(name, cells, w=9):
    return name.ljust(10) + " ".join(
        (f"{c:{w}.3f}" if isinstance(c, float) else str(c).rjust(w))
        for c in cells)
