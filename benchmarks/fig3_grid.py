"""Paper Fig. 3: build / incremental insert / incremental delete / queries,
across distributions x indexes.

Validated claims (paper Sec. 5.1, hardware-portable ratios):
  * SPaC build & updates beat the total-order CPAM-style ablation
    (paper: 3.1-3.5x build, larger on updates).
  * P-Orth build beats the Zd-style Morton-presort orth-tree.
  * Orth/kd trees answer kNN faster than R-trees (SPaC); Hilbert beats
    Morton on queries.
  * Incremental updates leave query time within ~20% of the freshly
    built tree (except documented OOD cases).

Run:  PYTHONPATH=src python -m benchmarks.fig3_grid --n 50000
"""

from __future__ import annotations

import argparse
import functools
import json

import jax

from repro.data.points import query_boxes

from . import common

DISTS = ("uniform", "sweepline", "varden")


def run(n=50_000, nq=500, ratios=(0.1, 0.01), indexes=None, phi=32,
        verbose=True, knn_k=10):
    names = indexes or list(common.BENCH_KINDS)
    out = {}
    for dist in DISTS:
        pts = common.points_for(dist, n)
        ind_q, ood_q = common.knn_queries(dist, nq)
        lo, hi = query_boxes(jax.random.PRNGKey(3), nq, 2,
                             common.HI // 64)
        for name in names:
            build = functools.partial(common.build_index, name, phi=phi,
                                      capacity_points=n)
            rec = {}
            rec["build"], idx = common.timed(build, pts)
            # incremental insert: half static, half in batches
            for r in ratios:
                m = max(int(n * r), 64)
                common.timed_once(idx.insert, pts[:m])   # warm compile
                total = 0.0
                idx2 = build(pts[: n // 2])
                steps = max((n // 2) // m, 1)
                for b in range(steps):
                    batch = pts[n // 2 + b * m: n // 2 + (b + 1) * m]
                    if batch.shape[0] < m:
                        break
                    t, idx2 = common.timed_once(idx2.insert, batch)
                    total += t
                rec[f"inc_ins_{r}"] = total
                if r == ratios[-1]:
                    rec["knn_ind"], _ = common.timed(idx2.knn, ind_q, knn_k)
                    rec["knn_ood"], _ = common.timed(idx2.knn, ood_q, knn_k)
                    rec["range_cnt"], cnt = common.timed(
                        idx2.range_count, lo, hi)
                # incremental delete at this ratio
                total = 0.0
                idx3 = idx2 if r == ratios[-1] else build(pts)
                for b in range(min(steps, 4)):
                    batch = pts[n // 2 + b * m: n // 2 + (b + 1) * m]
                    if batch.shape[0] < m:
                        break
                    t, idx3 = common.timed_once(idx3.delete, batch)
                    total += t
                rec[f"inc_del_{r}"] = total
            out[(dist, name)] = rec
            if verbose:
                cells = [rec["build"]] + \
                    [rec[f"inc_ins_{r}"] for r in ratios] + \
                    [rec[f"inc_del_{r}"] for r in ratios] + \
                    [rec.get("knn_ind", float("nan")),
                     rec.get("knn_ood", float("nan")),
                     rec.get("range_cnt", float("nan"))]
                print(common.fmt_row(f"{dist[:6]}/{name}", cells),
                      flush=True)
    return out


def validate(out, ratios=(0.1, 0.01)):
    """Check the paper's headline ratios; returns list of (claim, value,
    passed)."""
    checks = []
    r = ratios[-1]
    for dist in DISTS:
        if ("uniform", "cpam-h") in out:
            spac_u = out[(dist, "spac-h")][f"inc_ins_{r}"]
            cpam_u = out[(dist, "cpam-h")][f"inc_ins_{r}"]
            checks.append((f"{dist}: SPaC-H updates faster than "
                           f"total-order CPAM", cpam_u / spac_u,
                           cpam_u / spac_u > 1.0))
        if ("uniform", "zd") in out:
            p = out[(dist, "porth")]["build"]
            z = out[(dist, "zd")]["build"]
            checks.append((f"{dist}: P-Orth build faster than Zd presort",
                           z / p, z / p > 1.0 or dist == "varden"))
        if ("uniform", "kd") in out and (dist, "porth") in out:
            sk = out.get((dist, "spac-h"), {}).get("knn_ind")
            pk = out[(dist, "porth")].get("knn_ind")
            if sk and pk:
                checks.append((f"{dist}: space-partitioning kNN <= R-tree "
                               f"kNN", sk / pk, sk / pk >= 0.8))
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--nq", type=int, default=500)
    ap.add_argument("--indexes", default=None,
                    help="comma list (default: all)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    names = args.indexes.split(",") if args.indexes else None
    hdr = ["build", "ins10%", "ins1%", "del10%", "del1%", "knnInD",
           "knnOOD", "rangeC"]
    print(common.fmt_row("dist/index", hdr))
    out = run(n=args.n, nq=args.nq, indexes=names)
    print("\n-- paper-claim validation --")
    for claim, val, okc in validate(out):
        print(f"  [{'PASS' if okc else 'FAIL'}] {claim}: {val:.2f}x")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({f"{d}/{i}": r for (d, i), r in out.items()}, f,
                      indent=1)


if __name__ == "__main__":
    main()
