"""Benchmark driver: one section per paper table/figure + roofline.

``python -m benchmarks.run`` runs the full CPU suite at reduced sizes
(this container has 1 core; the paper used 112). ``--quick`` shrinks
further for smoke checks; ``--full`` enlarges. The dry-run/roofline
section only *reads* previously produced results/dryrun_*.jsonl (the
512-device dry-run must run in its own process because of XLA_FLAGS).
"""

from __future__ import annotations

import argparse
import glob
import time


def section(title):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", default="", help="comma list of sections")
    args = ap.parse_args()
    n = 10_000 if args.quick else (200_000 if args.full else 30_000)
    nq = 200 if args.quick else 400
    skip = set(args.skip.split(",")) if args.skip else set()
    t_start = time.time()

    if "fig3" not in skip:
        section(f"Fig. 3 — build/update/query grid (n={n})")
        from . import common, fig3_grid
        hdr = ["build", "ins10%", "ins1%", "del10%", "del1%", "knnInD",
               "knnOOD", "rangeC"]
        print(common.fmt_row("dist/index", hdr))
        out = fig3_grid.run(n=n, nq=nq)
        print("\n-- paper-claim validation --")
        for claim, val, okc in fig3_grid.validate(out):
            print(f"  [{'PASS' if okc else 'FAIL'}] {claim}: {val:.2f}x")

    if "fig4" not in skip:
        section(f"Fig. 4 — kNN vs k (n={n}, varden)")
        from . import common, fig4_knn
        print(common.fmt_row("index", [f"InD k={k}" for k in fig4_knn.KS]
                             + [f"OOD k={k}" for k in fig4_knn.KS]))
        fig4_knn.run(n=n, nq=nq)

    if "fig5" not in skip:
        section(f"Fig. 5 — range-list vs output size (n={n})")
        from . import common, fig5_range
        print(common.fmt_row("index",
                             [f"t s={s}" for s in fig5_range.SIDES]
                             + [f"out s={s}" for s in fig5_range.SIDES]))
        fig5_range.run(n=n, nq=max(nq // 2, 100))

    if "fig10" not in skip:
        section(f"Fig. 10 — single-batch update size sweep (n={2 * n})")
        from . import common, fig10_batch
        print(common.fmt_row("index",
                             [f"ins {r}" for r in fig10_batch.RATIOS]
                             + [f"del {r}" for r in fig10_batch.RATIOS]))
        fig10_batch.run(n=2 * n)

    if "fig9" not in skip:
        section(f"Fig. 9 — 3D datasets (n={max(n // 2, 10_000)})")
        from . import common, fig9_3d
        print(common.fmt_row("dist/index",
                             ["build", "ins 1%", "del 1%", "knn10"]))
        fig9_3d.run(n=max(n // 2, 10_000))

    if "roofline" not in skip:
        section(f"Roofline — spatial kernels (n={n}) + dry-run records")
        from . import roofline
        print(roofline.spatial_table(roofline.spatial_sweep(
            n=n, nq=max(nq // 2, 100), verbose=False)))
        paths = sorted(glob.glob("results/dryrun_*.jsonl"))
        if paths:
            recs = roofline.load(paths)
            for mesh in ("16x16", "2x16x16"):
                if any(m == mesh for (_, _, m) in recs):
                    print(f"\n-- mesh {mesh} --")
                    print(roofline.table(recs, mesh))
        else:
            print("(no dry-run records; run: PYTHONPATH=src python -m "
                  "repro.launch.dryrun --arch all --mesh both --out "
                  "results/dryrun.jsonl)")

    print(f"\ntotal benchmark time: {time.time() - t_start:,.0f}s")


if __name__ == "__main__":
    main()
