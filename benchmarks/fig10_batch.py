"""Paper Fig. 10: single-batch insert/delete time vs batch size.

Validates near-linear scaling of one batch update with batch size (the
paper's O(m log n) work bound) and the SPaC vs P-Orth ordering.

``--json`` records update throughput (points/s) per (backend, op,
batch ratio) under ``results/`` — mirrors ``fig4_knn.py --json``, the
bench trajectory baseline.

Run:  PYTHONPATH=src python -m benchmarks.fig10_batch --n 100000
      PYTHONPATH=src python -m benchmarks.fig10_batch --n 50000 --json
"""

from __future__ import annotations

import argparse

from . import common

RATIOS = (0.001, 0.01, 0.1)


def run(n=100_000, dist="uniform", indexes=None, phi=32, verbose=True):
    names = indexes or ["porth", "spac-h", "spac-z", "kd"]
    pts = common.points_for(dist, n)
    extra = common.points_for(dist, int(n * 0.1), seed=5)
    out = {}
    for name in names:
        idx = common.build_index(name, pts, phi=phi,
                                 capacity_points=int(n * 1.2))
        rec = {}
        for r in RATIOS:
            m = max(int(n * r), 64)
            rec[f"ins_{r}"], _ = common.timed(idx.insert, extra[:m])
            rec[f"del_{r}"], _ = common.timed(idx.delete, pts[:m])
        out[name] = rec
        if verbose:
            print(common.fmt_row(name, [rec[f"ins_{r}"] for r in RATIOS]
                                 + [rec[f"del_{r}"] for r in RATIOS]),
                  flush=True)
    return out


def throughput_records(out, n: int):
    """Flatten run() output to update points/s per (backend, op,
    ratio) — the fig4_knn.py --json shape."""
    return {name: {key: max(int(n * r), 64) / rec[key]
                   for r in RATIOS
                   for key in (f"ins_{r}", f"del_{r}")}
            for name, rec in out.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dist", default="uniform")
    ap.add_argument("--json", nargs="?", const="results/fig10_batch.json",
                    default=None, metavar="PATH",
                    help="write update points/s per (backend, op, ratio)")
    args = ap.parse_args()
    print(common.fmt_row("index", [f"ins {r}" for r in RATIOS]
                         + [f"del {r}" for r in RATIOS]))
    out = run(n=args.n, dist=args.dist)
    if args.json:
        common.write_json(
            args.json,
            dict(n=args.n, dist=args.dist,
                 update_pts_per_s=throughput_records(out, args.n)),
            "update points/s per (backend, op, ratio)")


if __name__ == "__main__":
    main()
