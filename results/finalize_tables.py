"""Insert the optimized roofline table + baseline/optimized deltas into
EXPERIMENTS.md (run once the *_opt.jsonl sweeps are complete)."""

import json
import sys

sys.path.insert(0, ".")
from benchmarks.roofline import load, table  # noqa: E402

opt = load(["results/dryrun_single_opt.jsonl"])
base = load(["results/dryrun_single.jsonl"])
tbl = table(opt, "16x16")

# summary deltas vs baseline
lines = ["", "Collective-term baseline -> optimized (single pod):", "```"]
for key in sorted(opt):
    a, s, m = key
    if key in base and base[key].get("ok") and opt[key].get("ok"):
        b = base[key]["terms"]["collective_s"]
        o = opt[key]["terms"]["collective_s"]
        if b > 0 and o > 0:
            lines.append(f"{a:26s} {s:12s} {b:10.3e} -> {o:10.3e}"
                         f"  ({b / o:6.1f}x)")
lines.append("```")

marker = ("(regenerate: `python -m benchmarks.roofline "
          "results/dryrun_single_opt.jsonl`)\n— inserted at finalization "
          "from results/dryrun_single_opt.jsonl.")
repl = ("(regenerate: `python -m benchmarks.roofline "
        "results/dryrun_single_opt.jsonl`)\n\n```\n" + tbl + "\n```\n"
        + "\n".join(lines))

src = open("EXPERIMENTS.md").read()
assert marker in src, "marker not found"
open("EXPERIMENTS.md", "w").write(src.replace(marker, repl))
n_ok = sum(1 for r in opt.values() if r.get("ok"))
print(f"inserted: {n_ok}/{len(opt)} single-pod cells ok")

multi = load(["results/dryrun_multi_opt.jsonl"])
n_ok_m = sum(1 for r in multi.values() if r.get("ok"))
print(f"multi-pod: {n_ok_m}/{len(multi)} cells ok")
