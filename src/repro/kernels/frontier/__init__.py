"""Fused frontier-kNN kernel: on-chip traversal with compensated distances.

The chunked frontier in ``core/queries.py`` pays a per-query ``argsort``
over all R rows plus gather-heavy ``while_loop`` chunk bodies that never
touch the MXU.  This package fuses that traversal into one launch:

* rows are packed into contiguous *groups* of ``block_r`` rows once
  (``prep.py``), so the traversal order is a per-query-*block* argsort
  over G = ceil(R / block_r) group lower bounds — not R rows per query;
* candidate groups are scored with the centered MXU identity
  ``|q-c|^2 - 2(q-c)(p-c) + |p-c|^2`` (``c`` = group bbox midpoint), which
  is bit-exact against the frontier's ``(q-p)^2`` whenever the *centered*
  intermediates stay in the f32-exact window — the spatial-locality regime
  the index's SFC leaf ordering guarantees; the selected k hits are then
  rescored with the direct ``(q-p)^2`` (``ops.py``), so the *returned*
  distances match the chunked route bit-for-bit even when a tile's
  spread dwarfs the neighbor distances and the identity cancels;
* the running top-k merge and the frontier cursor live in VMEM scratch,
  and the bbox-lower-bound early exit is a per-block ``pl.when`` skip, so
  converged query blocks stop reading HBM (``kernel.py``);
* ``ref.py`` is a pure-jnp ``while_loop`` mirror sharing the same prep
  and the same distance expression graph — bit-identical to the kernel in
  interpret mode and the fast CPU spelling behind ``impl="auto"``.

Routing lives in ``ops.py`` (canonical spellings: ``auto`` / ``pallas`` /
``pallas-interpret`` / ``ref``); tile defaults in ``tuning.py`` come from
``benchmarks/roofline.py --block-sweep``, not guesses.
"""

from repro.kernels.frontier.ops import (  # noqa: F401
    FRONTIER_IMPLS,
    knn_frontier,
    knn_frontier_impl,
)
