"""Fused frontier-kNN Pallas kernel.

One launch over a ``(query_blocks, groups)`` grid.  The per-block group
visit order and lower bounds arrive as scalar-prefetch operands, so the
point tile for step ``j`` is fetched data-dependently via the BlockSpec
``index_map`` — the gather the chunked frontier did on the host happens
in the kernel's pipeline instead.  The running top-k lives in VMEM
scratch across the inner grid axis, and a per-block ``pl.when`` skips the
whole tile (matmul *and* its HBM reads) once the sorted lower bound
passes the block's worst kth-best distance.

Distances use the centered MXU identity: points are pre-centered per
group (``prep.py``) and the query block subtracts the same center before
the matmul, so intermediates stay tile-local and the result is bit-exact
against the frontier's ``(q-p)^2`` in the regime the index guarantees
(spatially tight groups).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.frontier.prep import BIG, FrontierPrep


def _tile_distances(qc, pc, ok):
    """Centered ``|qc|^2 - 2 qc.pc + |pc|^2`` for one (block_q, P) tile.

    Shared verbatim by the jnp reference (``ref.py``) so both spellings
    evaluate the identical expression graph — bit-parity by construction,
    not by tolerance.
    """
    qn = jnp.sum(qc * qc, axis=1)
    pn = jnp.sum(pc * pc, axis=1)
    cross = jax.lax.dot_general(qc, pc, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    d2 = qn[:, None] - 2.0 * cross + pn[None, :]
    return jnp.where(ok[None, :], jnp.maximum(d2, 0.0), BIG)


def _merge_topk(dist, idx, d2, ids, k):
    """Merge a tile's distances into the running top-k (shared with ref)."""
    all_d = jnp.concatenate([dist, d2], axis=1)
    all_i = jnp.concatenate([idx, ids], axis=1)
    neg, arg = jax.lax.top_k(-all_d, k)
    return -neg, jnp.take_along_axis(all_i, arg, axis=1)


def _frontier_kernel(order_ref, glb_ref, q_ref, p_ref, ok_ref, c_ref,
                     d2_ref, id_ref, dist_scr, idx_scr, *, k, ppg):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dist_scr[...] = jnp.full_like(dist_scr[...], BIG)
        idx_scr[...] = jnp.full_like(idx_scr[...], -1)

    # Early exit: group bounds arrive ascending, and the block's worst
    # kth-best only shrinks, so once a bound fails it fails for every
    # later step — the predicated skip visits exactly the same prefix the
    # reference while_loop does.
    live = glb_ref[i, j] <= jnp.max(dist_scr[:, k - 1])

    @pl.when(live)
    def _step():
        g = order_ref[i, j]
        qc = q_ref[...] - c_ref[...]                    # (block_q, D)
        d2 = _tile_distances(qc, p_ref[...], ok_ref[...])
        ids = g * ppg + jax.lax.broadcasted_iota(
            jnp.int32, d2.shape, 1)
        dist_scr[...], idx_scr[...] = _merge_topk(
            dist_scr[...], idx_scr[...], d2, ids, k)

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        d2_ref[...] = dist_scr[...]
        id_ref[...] = jnp.where(dist_scr[...] >= BIG, -1, idx_scr[...])


def knn_frontier_pallas(pr: FrontierPrep, *, k: int,
                        interpret: bool = False):
    """Run the fused kernel over prepared operands; returns (d2, ids).

    Outputs are in sorted-query order, shape ``(Qp, k)`` — ``ops.py``
    undoes the sort and padding.
    """
    nqb, G = pr.order.shape
    bq, P = pr.block_q, pr.points_per_group
    D = pr.qs.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nqb, G),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j, o, b: (i, 0)),
            pl.BlockSpec((P, D), lambda i, j, o, b: (o[i, j], 0)),
            pl.BlockSpec((P,), lambda i, j, o, b: (o[i, j],)),
            pl.BlockSpec((1, D), lambda i, j, o, b: (o[i, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j, o, b: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j, o, b: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
    )
    fn = pl.pallas_call(
        functools.partial(_frontier_kernel, k=k, ppg=P),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((pr.qs.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((pr.qs.shape[0], k), jnp.int32),
        ],
        interpret=interpret,
    )
    d2, ids = fn(pr.order, pr.glb, pr.qs, pr.pts, pr.ok, pr.centers)
    return d2, ids
