"""Pure-jnp mirror of the fused frontier kernel.

Shares ``prep.prepare`` and the kernel's tile expressions
(``_tile_distances`` / ``_merge_topk``) so its outputs are bit-identical
to the interpret-mode kernel: same operands, same expression graph, same
visit prefix (the ``while_loop`` stops at the first failed lower bound —
exactly the set of steps the kernel's ``pl.when`` lets through).

This is also the fast CPU spelling behind ``impl="auto"``: one argsort
over G groups per query *block* and contiguous ``dynamic_slice`` tiles
fed to BLAS, versus the chunked frontier's per-query argsort over all R
rows and gather-heavy chunk bodies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.frontier.kernel import _merge_topk, _tile_distances
from repro.kernels.frontier.prep import BIG, FrontierPrep


def knn_frontier_ref(pr: FrontierPrep, *, k: int):
    """Traverse prepared groups per query block; returns (d2, ids).

    Outputs are in sorted-query order, shape ``(Qp, k)`` — ``ops.py``
    undoes the sort and padding.
    """
    nqb, G = pr.order.shape
    bq, P = pr.block_q, pr.points_per_group
    D = pr.qs.shape[1]
    qblocks = pr.qs.reshape(nqb, bq, D)

    def block(qb, order_b, glb_b):
        def cond(st):
            j, dist, _ = st
            return (j < G) & (glb_b[j] <= jnp.max(dist[:, k - 1]))

        def body(st):
            j, dist, idx = st
            g = order_b[j]
            c = jax.lax.dynamic_slice_in_dim(pr.centers, g, 1)   # (1, D)
            p = jax.lax.dynamic_slice_in_dim(pr.pts, g * P, P)   # (P, D)
            okt = jax.lax.dynamic_slice_in_dim(pr.ok, g * P, P)
            d2 = _tile_distances(qb - c, p, okt)
            ids = g * P + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
            dist, idx = _merge_topk(dist, idx, d2, ids, k)
            return j + 1, dist, idx

        init = (jnp.int32(0),
                jnp.full((bq, k), BIG, jnp.float32),
                jnp.full((bq, k), -1, jnp.int32))
        _, dist, idx = jax.lax.while_loop(cond, body, init)
        return dist, jnp.where(dist >= BIG, -1, idx)

    d2, ids = jax.vmap(block)(qblocks, pr.order, pr.glb)
    return d2.reshape(-1, k), ids.reshape(-1, k)
