"""Impl routing for the fused frontier kernel.

Canonical spellings only (the engine and the kernel layer share one
vocabulary — see ``kernels/knn/ops.py`` for the same rule on the flat
kernel):

* ``auto``             — ``pallas`` on TPU, ``ref`` elsewhere
* ``pallas``           — compiled Pallas TPU kernel
* ``pallas-interpret`` — same kernel under the Pallas interpreter (CPU CI)
* ``ref``              — jnp while_loop mirror, bit-identical to the kernel

``knn_frontier_impl`` is the unjitted spelling for use inside
``shard_map`` regions (the nested-jit miscompile — ROADMAP "Known
constraints"); ``knn_frontier`` is the jitted module-level alias.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.frontier import kernel, ref, tuning
from repro.kernels.frontier.prep import BIG, prepare

FRONTIER_IMPLS = ("auto", "pallas", "pallas-interpret", "ref")


def canonical_impl(impl: str) -> str:
    """Validate an impl spelling; reject legacy aliases loudly."""
    if impl == "interpret":
        raise ValueError(
            'impl="interpret" is not a spelling; use the canonical '
            '"pallas-interpret" (one name across engine and kernels)')
    if impl not in FRONTIER_IMPLS:
        raise ValueError(
            f"unknown frontier impl {impl!r}; expected one of "
            f"{FRONTIER_IMPLS}")
    return impl


def knn_frontier_impl(pts, valid, active, bbox_lo, bbox_hi, queries, *,
                      k: int, impl: str = "auto",
                      block_q=None, block_p=None):
    """Fused frontier kNN over leaf-view arrays; returns (d2, ids).

    ``ids`` are flat ``row * C + col`` candidate ids (-1 past the end),
    matching the chunked frontier in ``core/queries.py``. The centered
    MXU identity *selects* the candidates on-chip; the returned
    distances are then rescored with the direct ``|q - p|^2`` the
    chunked traversal uses, so scores stay well-conditioned even when
    one tile spans a whole shard (tile-local spread >> neighbor
    distances, where the expanded identity cancels catastrophically)
    and are bit-identical to the chunked route for the same candidate.
    """
    impl = canonical_impl(impl)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    bq, bp = tuning.tiles(impl, block_q, block_p)
    pr = prepare(pts, valid, active, bbox_lo, bbox_hi, queries,
                 block_q=bq, block_p=bp)
    if impl == "ref":
        d2, ids = ref.knn_frontier_ref(pr, k=k)
    else:
        d2, ids = kernel.knn_frontier_pallas(
            pr, k=k, interpret=(impl == "pallas-interpret"))
    q = queries.shape[0]
    d2, ids = d2[:q][pr.inv], ids[:q][pr.inv]
    flat = pts.astype(jnp.float32).reshape(-1, pts.shape[-1])
    diff = flat[jnp.clip(ids, 0)] - \
        queries.astype(jnp.float32)[:, None, :]
    d2 = jnp.where(ids < 0, BIG, jnp.sum(diff * diff, axis=-1))
    d2, ids = jax.lax.sort((d2, ids), dimension=-1, num_keys=2)
    return d2, jnp.where(d2 >= BIG, -1, ids)


knn_frontier = jax.jit(
    knn_frontier_impl,
    static_argnames=("k", "impl", "block_q", "block_p"))
