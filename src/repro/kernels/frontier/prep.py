"""Shared host-side prep for the fused frontier kernel and its jnp ref.

Everything here is plain jnp (jit-safe, shard_map-safe) and is shared by
both spellings so their inputs — group packing, centers, per-block
traversal order — are bit-identical by construction.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

BIG = 3.4e38  # python float: kernels close over it without a captured const

_MORTON_BITS = 10  # 10 bits/dim -> <= 30-bit codes for D <= 3


def morton_key(q: jnp.ndarray, bits: int = _MORTON_BITS) -> jnp.ndarray:
    """Quantized morton code per query, for spatial blocking.

    Queries are sorted by this key before being cut into blocks of
    ``block_q`` so each block is spatially tight — the per-block shared
    traversal order and early-exit threshold only prune well when the
    block's queries want the same groups.  (Local impl rather than
    ``core.sfc`` to keep kernels importable without the core package.)
    """
    qf = q.astype(jnp.float32)
    lo = jnp.min(qf, axis=0)
    span = jnp.maximum(jnp.max(qf, axis=0) - lo, jnp.float32(1e-30))
    top = jnp.float32((1 << bits) - 1)
    cell = jnp.clip((qf - lo) / span * top, 0.0, top).astype(jnp.uint32)
    code = jnp.zeros(q.shape[0], jnp.uint32)
    for b in range(bits):
        for d in range(q.shape[1]):
            code = code | (((cell[:, d] >> b) & 1) << (b * q.shape[1] + d))
    return code


class FrontierPrep(NamedTuple):
    """Kernel-ready operands; see ``prepare`` for shapes."""

    qs: jnp.ndarray          # (Qp, D) f32 sorted+padded queries
    pts: jnp.ndarray         # (G*P, D) f32 grouped points, centered per group
    ok: jnp.ndarray          # (G*P,) bool slot validity
    order: jnp.ndarray       # (nqb, G) int32 group visit order per block
    glb: jnp.ndarray         # (nqb, G) f32 group lower bounds, ascending
    centers: jnp.ndarray     # (G, D) f32 group centers (0 for dead groups)
    inv: jnp.ndarray         # (Q,) int32 undoes the query sort
    block_q: int
    points_per_group: int


def prepare(pts, valid, active, bbox_lo, bbox_hi, queries, *,
            block_q: int, block_p: int) -> FrontierPrep:
    """Pack rows into groups and order them per query block.

    Rows are grouped ``block_r = max(1, block_p // C)`` at a time, so one
    kernel tile is ``P = block_r * C`` points and the flat candidate id of
    slot ``o`` in group ``g`` is ``g * P + o`` — the same ``row * C + col``
    id space the engine already uses, because groups are contiguous rows.
    """
    R, C, D = pts.shape
    block_r = max(1, block_p // C)
    P = block_r * C
    G = -(-R // block_r)
    pad_r = G * block_r - R

    ok = valid & active[:, None]
    pts_f = pts.astype(jnp.float32)
    lo_f = jnp.where(active[:, None], bbox_lo.astype(jnp.float32), BIG)
    hi_f = jnp.where(active[:, None], bbox_hi.astype(jnp.float32), -BIG)
    if pad_r:
        pts_f = jnp.concatenate(
            [pts_f, jnp.zeros((pad_r, C, D), jnp.float32)])
        ok = jnp.concatenate([ok, jnp.zeros((pad_r, C), bool)])
        lo_f = jnp.concatenate([lo_f, jnp.full((pad_r, D), BIG)])
        hi_f = jnp.concatenate([hi_f, jnp.full((pad_r, D), -BIG)])

    glo = lo_f.reshape(G, block_r, D).min(axis=1)          # (G, D)
    ghi = hi_f.reshape(G, block_r, D).max(axis=1)
    galive = glo[:, 0] <= ghi[:, 0]
    # Midpoint center: glo + ghi is exact for coords < 2^23 (sum < 2^24)
    # and the * 0.5 never rounds, so centers inherit the data's exactness.
    centers = jnp.where(galive[:, None], (glo + ghi) * jnp.float32(0.5), 0.0)

    pts_g = (pts_f.reshape(G, P, D) - centers[:, None, :]).reshape(G * P, D)
    ok_g = ok.reshape(G * P)

    Q = queries.shape[0]
    qf = queries.astype(jnp.float32)
    perm = jnp.argsort(morton_key(qf)).astype(jnp.int32)
    inv = jnp.argsort(perm).astype(jnp.int32)
    qs = qf[perm]
    nqb = -(-Q // block_q)
    pad_q = nqb * block_q - Q
    if pad_q:
        # Pad with the *last* sorted query so the tail block stays tight.
        qs = jnp.concatenate(
            [qs, jnp.broadcast_to(qs[-1:], (pad_q, D))])

    qb = qs.reshape(nqb, block_q, D)
    blo, bhi = qb.min(axis=1), qb.max(axis=1)              # (nqb, D)
    gap = jnp.maximum(jnp.maximum(glo[None] - bhi[:, None],
                                  blo[:, None] - ghi[None]), 0.0)
    glb = jnp.where(galive[None, :], (gap * gap).sum(-1), BIG)
    order = jnp.argsort(glb, axis=1).astype(jnp.int32)     # (nqb, G)
    glb = jnp.take_along_axis(glb, order, axis=1)

    return FrontierPrep(qs=qs, pts=pts_g, ok=ok_g, order=order, glb=glb,
                        centers=centers, inv=inv, block_q=block_q,
                        points_per_group=P)
