"""Tile defaults for the fused frontier kernel.

These come from ``benchmarks/roofline.py --block-sweep`` (achieved GB/s
per (block_q, block_p) cell is recorded as obs counters and the chosen
cell is emitted in ``results/roofline.json`` under ``block_sweep``), not
from guesses.  Re-run the sweep and update here when the kernel or the
smoke-scale workload changes:

    PYTHONPATH=src python benchmarks/roofline.py --block-sweep --json
"""

from __future__ import annotations

# impl -> (block_q, block_p).  block_p is a *point* budget per tile; prep
# rounds it to whole rows (block_r = block_p // C, P = block_r * C).
_DEFAULT_TILES = {
    # CPU while_loop spelling: small query blocks keep the early exit
    # tight (one straggler query can't pin a whole block on the scan).
    "ref": (8, 512),
    # MXU spellings: 128-query tiles amortize the point-tile reads and
    # match the MXU's 128-lane geometry.
    "pallas": (128, 512),
    "pallas-interpret": (16, 512),
}


def tiles(impl: str, block_q=None, block_p=None):
    """Resolve (block_q, block_p), honoring explicit overrides."""
    dq, dp = _DEFAULT_TILES[impl]
    return int(block_q or dq), int(block_p or dp)
