"""Sieve = fused histogram kernel + counting-sort offsets + scatter.

``sieve_partition`` reorders points so equal buckets are contiguous
(stable), returning (order, bucket_of_point, bucket_offsets) — a drop-in
counting-sort replacement for the argsort used in the baseline P-Orth
build path (the paper's point: counting sort beats comparison sort here).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import sieve_histogram_pallas
from .ref import bucket_ids_ref, sieve_histogram_ref


@functools.partial(jax.jit, static_argnames=("lam", "block_n", "impl"))
def sieve_histogram(pts, cell_lo, cell_hi, *, lam: int, block_n: int = 1024,
                    impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return sieve_histogram_pallas(pts, cell_lo, cell_hi, lam=lam,
                                      block_n=block_n)
    if impl == "interpret":
        return sieve_histogram_pallas(pts, cell_lo, cell_hi, lam=lam,
                                      block_n=block_n, interpret=True)
    return sieve_histogram_ref(pts, cell_lo, cell_hi, lam=lam,
                               block_n=block_n)


@functools.partial(jax.jit, static_argnames=("lam", "block_n", "impl"))
def sieve_partition(pts, cell_lo, cell_hi, *, lam: int, block_n: int = 1024,
                    impl: str = "auto"):
    """Stable counting-sort of points by sieve bucket.

    Returns (dest, bucket, offsets): dest[i] = target position of point i;
    offsets[b] = start of bucket b. Work O(n + blocks * buckets) — the
    paper's I/O-efficient sieve, vs O(n log n) comparison sort.
    """
    n, dim = pts.shape
    n_buckets = 2 ** (lam * dim)
    hist = sieve_histogram(pts, cell_lo, cell_hi, lam=lam, block_n=block_n,
                           impl=impl)                    # (nb, K)
    bucket = bucket_ids_ref(pts, cell_lo, cell_hi, lam=lam)
    # matrix-transpose redistribution [9, 19]: offsets in (bucket, block)
    # major order give a stable global counting sort.
    flat = hist.T.reshape(-1)                            # (K * nb,)
    starts = (jnp.cumsum(flat) - flat).reshape(n_buckets, -1)  # (K, nb)
    blk = jnp.arange(n, dtype=jnp.int32) // block_n
    base = starts[bucket, blk]
    # rank within (block, bucket): occurrence index via one cumsum per bucket
    # — computed with a segmented trick: sort-free, O(n * 1) using the
    # within-block running count.
    onehot_rank = _within_block_rank(bucket, blk, n_buckets, block_n)
    dest = base.astype(jnp.int32) + onehot_rank
    return dest, bucket, starts[:, 0]


def _within_block_rank(bucket, blk, n_buckets: int, block_n: int):
    """occurrence index of each point among same-bucket points in its block."""
    n = bucket.shape[0]
    key = blk * n_buckets + bucket
    # stable argsort of the (block, bucket) key gives grouped order; rank =
    # position - group start (same machinery as leafstore.group_occurrence).
    perm = jnp.argsort(key, stable=True).astype(jnp.int32)
    skey = key[perm]
    idx = jnp.arange(n, dtype=jnp.int32)
    change = jnp.concatenate([jnp.ones((1,), bool), skey[1:] != skey[:-1]])
    first = jax.lax.associative_scan(jnp.maximum, jnp.where(change, idx, 0))
    rank_sorted = idx - first
    rank = jnp.zeros(n, jnp.int32).at[perm].set(rank_sorted)
    return rank
