"""Oracle: bucket-id + per-block histogram in pure jnp."""

from __future__ import annotations

import jax.numpy as jnp


def bucket_ids_ref(pts, cell_lo, cell_hi, *, lam: int):
    lo, hi = cell_lo, cell_hi
    dim = pts.shape[1]
    bucket = jnp.zeros(pts.shape[0], jnp.int32)
    for _ in range(lam):
        if jnp.issubdtype(pts.dtype, jnp.floating):
            mid = lo + (hi - lo) * 0.5
        else:
            mid = lo + (hi - lo) // 2
        gt = pts >= mid
        b = jnp.zeros(pts.shape[0], jnp.int32)
        for d in range(dim):
            b = b | (gt[:, d].astype(jnp.int32) << (dim - 1 - d))
        bucket = (bucket << dim) | b
        lo = jnp.where(gt, mid, lo)
        hi = jnp.where(gt, hi, mid)
    return bucket


def sieve_histogram_ref(pts, cell_lo, cell_hi, *, lam: int, block_n: int):
    n, dim = pts.shape
    n_buckets = 2 ** (lam * dim)
    nb = (n + block_n - 1) // block_n
    bucket = bucket_ids_ref(pts, cell_lo, cell_hi, lam=lam)
    blk = jnp.arange(n) // block_n
    return jnp.zeros((nb, n_buckets), jnp.int32).at[blk, bucket].add(1)
