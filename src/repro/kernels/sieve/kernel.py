"""Pallas sieve kernel: fused bucket-id + per-block histogram.

This is the paper's hot loop (Sec. 3.1): distribute points into the 2^(λD)
buckets of a λ-level skeleton. The CPU version blocks for cache; the TPU
version tiles points into VMEM, computes the bucket of each point by λ·D
midpoint *comparisons* (never materializing SFC codes — the paper's core
trick), and accumulates a per-tile histogram in a VMEM scratch accumulator.

Output = (num_blocks, n_buckets) histograms; the host-side counting-sort
offsets (exclusive scan over blocks × buckets, transposed — matching the
matrix-transpose redistribution of [9, 19]) and the scatter are cheap jnp
ops on top (ops.py).

One-hot trick: the per-tile histogram is a (block_n, n_buckets) one-hot
matmul against ones — MXU-friendly (int8/bf16 one-hots), the standard way
to histogram on a systolic array.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sieve_kernel(pts_ref, lo_ref, hi_ref, out_ref, *, lam: int, dim: int,
                  n_buckets: int, n_total: int, block_n: int):
    pts = pts_ref[...]                       # (Bn, D)
    lo = lo_ref[...]                         # (Bn, D) per-point cell bounds
    hi = hi_ref[...]
    i = pl.program_id(0)
    in_range = (i * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (pts.shape[0],), 0)) < n_total
    bucket = jnp.zeros(pts.shape[0], jnp.int32)
    for _ in range(lam):
        if jnp.issubdtype(pts.dtype, jnp.floating):
            mid = lo + (hi - lo) * 0.5
        else:
            mid = lo + (hi - lo) // 2
        gt = pts >= mid
        b = jnp.zeros(pts.shape[0], jnp.int32)
        for d in range(dim):
            b = b | (gt[:, d].astype(jnp.int32) << (dim - 1 - d))
        bucket = (bucket << dim) | b
        lo = jnp.where(gt, mid, lo)
        hi = jnp.where(gt, hi, mid)
    onehot = ((bucket[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (pts.shape[0], n_buckets), 1))
        & in_range[:, None]).astype(jnp.float32)
    out_ref[...] = jnp.sum(onehot, axis=0).astype(jnp.int32)[None, :]


def sieve_histogram_pallas(pts, cell_lo, cell_hi, *, lam: int,
                           block_n: int = 1024, interpret: bool = False):
    """Per-block bucket histograms.

    pts/cell_lo/cell_hi: (N, D) — each point carries its current cell bounds
    (gathered from its segment before the call). Returns
    (num_blocks, 2**(lam*D)) int32 histograms.
    """
    n, dim = pts.shape
    n_buckets = 2 ** (lam * dim)
    block_n = min(block_n, n)
    grid = ((n + block_n - 1) // block_n,)
    kernel = functools.partial(_sieve_kernel, lam=lam, dim=dim,
                               n_buckets=n_buckets, n_total=n,
                               block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, dim), lambda i: (i, 0)),
                  pl.BlockSpec((block_n, dim), lambda i: (i, 0)),
                  pl.BlockSpec((block_n, dim), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n_buckets), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], n_buckets), jnp.int32),
        interpret=interpret,
    )(pts, cell_lo, cell_hi)
