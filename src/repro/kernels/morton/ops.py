from __future__ import annotations

import functools

import jax

from .kernel import morton_encode_pallas
from .ref import morton_encode_ref


@functools.partial(jax.jit, static_argnames=("bits", "coord_bits", "impl"))
def morton_encode(pts, *, bits: int = 15, coord_bits: int = 20,
                  impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return morton_encode_pallas(pts, bits=bits, coord_bits=coord_bits)
    if impl == "interpret":
        return morton_encode_pallas(pts, bits=bits, coord_bits=coord_bits,
                                    interpret=True)
    return morton_encode_ref(pts, bits=bits, coord_bits=coord_bits)
