"""Oracle: quantize + Morton encode via the core sfc module."""

from __future__ import annotations

import jax.numpy as jnp

from ...core import sfc


def morton_encode_ref(pts, *, bits: int, coord_bits: int):
    shift = max(0, coord_bits - bits)
    return sfc.morton_encode(pts.astype(jnp.uint32) >> shift, bits)
