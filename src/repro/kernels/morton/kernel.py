"""Pallas Morton-encode kernel: fused quantize + bit-interleave.

The paper's Zd/SPaC pipelines spend a full read+write pass computing codes
(Sec. 3 'Issues'); on TPU the fix is fusing quantization and interleave into
one VMEM-resident pass over coordinate tiles (HBM traffic = read coords +
write codes, nothing else). Bit spreading uses the magic-mask shifts (VPU
int ops), vectorized over a (block_n,) lane tile per dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spread2(x):
    x = x & jnp.uint32(0xFFFF)
    x = (x | (x << 8)) & jnp.uint32(0x00FF00FF)
    x = (x | (x << 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x << 2)) & jnp.uint32(0x33333333)
    x = (x | (x << 1)) & jnp.uint32(0x55555555)
    return x


def _spread3(x):
    x = x & jnp.uint32(0x3FF)
    x = (x | (x << 16)) & jnp.uint32(0x030000FF)
    x = (x | (x << 8)) & jnp.uint32(0x0300F00F)
    x = (x | (x << 4)) & jnp.uint32(0x030C30C3)
    x = (x | (x << 2)) & jnp.uint32(0x09249249)
    return x


def _morton_kernel(pts_ref, out_ref, *, dim: int, shift: int):
    c = pts_ref[...].astype(jnp.uint32) >> shift
    if dim == 2:
        code = (_spread2(c[:, 0]) << 1) | _spread2(c[:, 1])
    else:
        code = ((_spread3(c[:, 0]) << 2) | (_spread3(c[:, 1]) << 1)
                | _spread3(c[:, 2]))
    out_ref[...] = code


def morton_encode_pallas(pts, *, bits: int, coord_bits: int,
                         block_n: int = 1024, interpret: bool = False):
    """pts: (N, D) int32 in [0, 2**coord_bits) -> (N,) uint32 Morton codes."""
    n, dim = pts.shape
    assert dim in (2, 3)
    block_n = min(block_n, n)
    grid = ((n + block_n - 1) // block_n,)
    shift = max(0, coord_bits - bits)
    kernel = functools.partial(_morton_kernel, dim=dim, shift=shift)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, dim), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(pts)
