"""Pallas TPU flash attention (block online-softmax), causal + GQA + SWA.

Used by the LM substrate (the serving/training hot spot). TPU-native tiling:
  * grid = (batch, q_heads, num_q_blocks, num_kv_blocks) — the last axis
    iterates fastest; VMEM scratch (m, l, acc) persists across kv blocks of
    the same q block (the standard TPU flash pattern);
  * q/k/v blocks live in VMEM via BlockSpec; MXU matmuls are (Bq, d)x(d, Bk)
    with Bq/Bk multiples of 128 on real hardware (tests use smaller tiles in
    interpret mode — the ref oracle is exact at any tile size);
  * GQA: the k/v index_map folds q-head -> kv-head (h // group);
  * causal + sliding-window masks are applied in-block (out-of-range kv
    blocks contribute nothing; with causal=True whole blocks above the
    diagonal are skipped via a cheap mask — grid pruning is a TODO noted in
    EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int | None,
                 block_q: int, block_k: int, seq_q: int, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (Bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (Bk, d)
    # zero padded kv rows (partial tail blocks): 0 * NaN would poison p @ v
    kv_valid = (ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_k,), 0)) < seq_kv
    k = jnp.where(kv_valid[:, None], k, 0.0)
    v = jnp.where(kv_valid[:, None], v, 0.0)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Bq, Bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    # queries index the *suffix* of the kv sequence (decode: q at the end)
    q_pos = q_pos + (seq_kv - seq_q)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_kv
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    # fully-masked rows: keep contributions at exactly zero
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Hq, Sq, d), k/v: (B, Hkv, Skv, d) -> (B, Hq, Sq, d).

    Sq may be shorter than Skv (decode: queries attend to a cache suffix
    alignment — query i sits at absolute position Skv - Sq + i).
    """
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    nq = (Sq + block_q - 1) // block_q
    nk = (Skv + block_k - 1) // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_q=Sq, seq_kv=Skv)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
