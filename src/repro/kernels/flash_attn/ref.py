"""Pure-jnp oracle for flash attention (exact softmax, f32 accumulation)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q: (B, Hq, Sq, d), k/v: (B, Hkv, Skv, d) -> (B, Hq, Sq, d)."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
