"""Jit'd dispatch wrapper: Pallas kernel on TPU, oracle elsewhere.

Model code calls ``attention(...)``; the dry-run (XLA:CPU, 512 fake devices)
lowers the pure-jnp path, real-TPU runs hit the kernel, and tests pin
``impl=`` explicitly.
"""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              impl: str = "auto", block_q: int = 128, block_k: int = 128):
    if impl == "auto":
        impl = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k)
    if impl == "interpret":
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=True)
    return attention_ref(q, k, v, causal=causal, window=window)
