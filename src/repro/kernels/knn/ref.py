"""Oracle: exact brute-force kNN in pure jnp (f32)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 3.4e38


def knn_ref(queries, points, ok, *, k: int):
    q = queries.astype(jnp.float32)
    p = points.astype(jnp.float32)
    d2 = jnp.sum((q[:, None, :] - p[None, :, :]) ** 2, axis=-1)
    d2 = jnp.where(ok[None, :], d2, BIG)
    neg, idx = jax.lax.top_k(-d2, k)
    d2k = -neg
    idx = jnp.where(d2k >= BIG, -1, idx)
    return d2k, idx
