from __future__ import annotations

import functools

import jax

from .kernel import knn_pallas
from .ref import knn_ref


def knn_bruteforce_impl(queries, points, ok, *, k: int, block_q: int = 128,
                        block_p: int = 512, impl: str = "auto"):
    """Unjitted :func:`knn_bruteforce` — use inside shard_map/pjit
    regions (nested ``jax.jit`` miscompiles there on some jax versions;
    see the query-engine note in ROADMAP.md)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return knn_pallas(queries, points, ok, k=k, block_q=block_q,
                          block_p=block_p)
    if impl == "interpret":
        return knn_pallas(queries, points, ok, k=k, block_q=block_q,
                          block_p=block_p, interpret=True)
    return knn_ref(queries, points, ok, k=k)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_p",
                                             "impl"))
def knn_bruteforce(queries, points, ok, *, k: int, block_q: int = 128,
                   block_p: int = 512, impl: str = "auto"):
    return knn_bruteforce_impl(queries, points, ok, k=k, block_q=block_q,
                               block_p=block_p, impl=impl)
