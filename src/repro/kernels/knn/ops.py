from __future__ import annotations

import functools

import jax

from .kernel import knn_pallas
from .ref import knn_ref

# Canonical impl spellings, shared verbatim with the engine's routing
# table and kernels/frontier: one vocabulary across layers.
KNN_KERNEL_IMPLS = ("auto", "pallas", "pallas-interpret", "ref")


def canonical_impl(impl: str) -> str:
    """Validate an impl spelling; reject legacy aliases loudly."""
    if impl == "interpret":
        raise ValueError(
            'impl="interpret" is not a spelling; use the canonical '
            '"pallas-interpret" (one name across engine and kernels)')
    if impl not in KNN_KERNEL_IMPLS:
        raise ValueError(
            f"unknown knn kernel impl {impl!r}; expected one of "
            f"{KNN_KERNEL_IMPLS}")
    return impl


def knn_bruteforce_impl(queries, points, ok, *, k: int, block_q: int = 128,
                        block_p: int = 512, impl: str = "auto"):
    """Unjitted :func:`knn_bruteforce` — use inside shard_map/pjit
    regions (nested ``jax.jit`` miscompiles there on some jax versions;
    see the query-engine note in ROADMAP.md)."""
    impl = canonical_impl(impl)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return knn_pallas(queries, points, ok, k=k, block_q=block_q,
                          block_p=block_p)
    if impl == "pallas-interpret":
        return knn_pallas(queries, points, ok, k=k, block_q=block_q,
                          block_p=block_p, interpret=True)
    return knn_ref(queries, points, ok, k=k)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_p",
                                             "impl"))
def knn_bruteforce(queries, points, ok, *, k: int, block_q: int = 128,
                   block_p: int = 512, impl: str = "auto"):
    return knn_bruteforce_impl(queries, points, ok, k=k, block_q=block_q,
                               block_p=block_p, impl=impl)
