"""Pallas kNN kernel: tiled distances + running top-k merge.

The query engine's inner loop (queries.py body): a block of queries scans
candidate point tiles, maintaining a per-query top-k. TPU mapping:
  * grid = (q_blocks, point_blocks), point axis fastest;
  * distances = |q|^2 - 2 q.p + |p|^2 via one MXU matmul per tile pair;
  * running top-k lives in VMEM scratch; the merge is a sort over
    (k + block_p) lanes — k is small (<= 64), so the merge is VPU-cheap
    relative to the distance matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 3.4e38


def _knn_kernel(q_ref, p_ref, ok_ref, d_out, i_out, dist_scr, idx_scr, *,
                k: int, block_p: int, n_pts: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dist_scr[...] = jnp.full_like(dist_scr, BIG)
        idx_scr[...] = jnp.full_like(idx_scr, -1)

    q = q_ref[...].astype(jnp.float32)          # (Bq, D)
    p = p_ref[...].astype(jnp.float32)          # (Bp, D)
    ok = ok_ref[...]                            # (Bp,)
    d2 = (jnp.sum(q * q, 1)[:, None] - 2.0 * jax.lax.dot_general(
        q, p, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + jnp.sum(p * p, 1)[None, :])
    gidx = j * block_p + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], p.shape[0]), 1)
    valid = (gidx < n_pts) & ok[None, :]
    d2 = jnp.where(valid, jnp.maximum(d2, 0.0), BIG)
    gidx = jnp.where(valid, gidx, -1)

    cat_d = jnp.concatenate([dist_scr[...], d2], axis=1)
    cat_i = jnp.concatenate([idx_scr[...], gidx], axis=1)
    neg, sel = jax.lax.top_k(-cat_d, k)
    dist_scr[...] = -neg
    idx_scr[...] = jnp.take_along_axis(cat_i, sel, axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        d_out[...] = dist_scr[...]
        i_out[...] = idx_scr[...]


def knn_pallas(queries, points, ok, *, k: int, block_q: int = 128,
               block_p: int = 512, interpret: bool = False):
    """Exact brute-force kNN: queries (Q, D) vs points (N, D) with validity
    mask ok (N,). Returns (d2 (Q, k) ascending, idx (Q, k), -1-padded)."""
    Q, dim = queries.shape
    N = points.shape[0]
    block_q = min(block_q, Q)
    block_p = min(block_p, N)
    grid = ((Q + block_q - 1) // block_q, (N + block_p - 1) // block_p)
    kernel = functools.partial(_knn_kernel, k=k, block_p=block_p, n_pts=N)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_q, dim), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_p, dim), lambda i, j: (j, 0)),
                  pl.BlockSpec((block_p,), lambda i, j: (j,))],
        out_specs=[pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_q, k), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Q, k), jnp.float32),
                   jax.ShapeDtypeStruct((Q, k), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((block_q, k), jnp.float32),
                        pltpu.VMEM((block_q, k), jnp.int32)],
        interpret=interpret,
    )(queries, points, ok)
