"""Pallas TPU kernels for the perf-critical hot spots.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper with platform dispatch), ref.py (pure-jnp oracle).
Validated in interpret mode on CPU; TPU is the compile target.

  * sieve      — the paper's hot loop: fused bucket-id + histogram
  * morton     — fused quantize + bit-interleave encode
  * knn        — tiled distance matmul + running top-k
  * bbox       — masked per-row min/max reduction
  * flash_attn — block online-softmax attention (LM substrate)
"""

from . import bbox, flash_attn, knn, morton, sieve  # noqa: F401
