from __future__ import annotations

import functools

import jax

from .kernel import row_bbox_pallas
from .ref import row_bbox_ref


@functools.partial(jax.jit, static_argnames=("block_r", "impl"))
def row_bbox(pts, valid, *, block_r: int = 256, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return row_bbox_pallas(pts, valid, block_r=block_r)
    if impl == "interpret":
        return row_bbox_pallas(pts, valid, block_r=block_r, interpret=True)
    return row_bbox_ref(pts, valid)
