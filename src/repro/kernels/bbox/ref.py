from __future__ import annotations

import jax.numpy as jnp


def row_bbox_ref(pts, valid):
    p = pts.astype(jnp.float32)
    m = valid[..., None]
    big = 3.4e38
    return (jnp.min(jnp.where(m, p, big), axis=1),
            jnp.max(jnp.where(m, p, -big), axis=1))
