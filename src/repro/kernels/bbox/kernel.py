"""Pallas segment-bbox kernel: masked per-row min/max reduction.

Maintaining bounding boxes is the R-tree's per-update obligation (paper
Sec. 2.3); rows are (R, C, D) leaf slots. TPU mapping: tile rows into VMEM,
reduce over the slot axis with masked min/max (VPU), one pass over HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bbox_kernel(pts_ref, valid_ref, lo_ref, hi_ref, *, big: float):
    pts = pts_ref[...].astype(jnp.float32)     # (Br, C, D)
    m = valid_ref[...][..., None]              # (Br, C, 1)
    lo_ref[...] = jnp.min(jnp.where(m, pts, big), axis=1)
    hi_ref[...] = jnp.max(jnp.where(m, pts, -big), axis=1)


def row_bbox_pallas(pts, valid, *, block_r: int = 256,
                    interpret: bool = False):
    """pts (R, C, D), valid (R, C) -> (lo, hi) each (R, D) float32."""
    R, C, dim = pts.shape
    block_r = min(block_r, R)
    grid = ((R + block_r - 1) // block_r,)
    big = 3.4e38
    kernel = functools.partial(_bbox_kernel, big=big)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, C, dim), lambda i: (i, 0, 0)),
                  pl.BlockSpec((block_r, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_r, dim), lambda i: (i, 0)),
                   pl.BlockSpec((block_r, dim), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, dim), jnp.float32),
                   jax.ShapeDtypeStruct((R, dim), jnp.float32)],
        interpret=interpret,
    )(pts, valid)
