"""RWKV6 "Finch" block: data-dependent-decay linear attention + channel mix.

Time-mix state is (B, H, hd, hd) per layer (attention-free: O(1) per decoded
token — why rwkv6 runs the long_500k cell natively). The sequential scan over
tokens is exact; a chunked formulation is a §Perf candidate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constraints as C

from .layers import rms_norm


def _token_shift(x, prev):
    """x_{t-1} with prev as the t=0 predecessor. x: (B, S, D), prev: (B, D)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def time_mix(x, p, cfg, cache=None):
    rw = cfg.rwkv
    B, S, D = x.shape
    hd = rw.head_dim
    H = D // hd
    r0 = rms_norm(x, p["ln"], cfg.norm_eps)
    prev = (jnp.zeros((B, D), x.dtype) if cache is None
            else cache["shift_t"])
    sx = _token_shift(r0, prev) - r0

    # data-dependent lerp (ddlerp) for the five projections
    xxx = r0 + sx * p["mu_x"]
    deltas = jnp.einsum(
        "pbsl,pld->pbsd",
        jnp.tanh(jnp.einsum("bsd,pdl->pbsl", xxx, p["mix_w1_p"])),
        p["mix_w2"])
    mw, mk, mv, mr, mg = deltas
    xw = r0 + sx * (p["mu_w"] + mw)
    xk = r0 + sx * (p["mu_k"] + mk)
    xv = r0 + sx * (p["mu_v"] + mv)
    xr = r0 + sx * (p["mu_r"] + mr)
    xg = r0 + sx * (p["mu_g"] + mg)

    r = jnp.einsum("bsd,de->bse", xr, p["Wr"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["Wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["Wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["Wg"]))
    w = p["w0"] + jnp.einsum(
        "bsl,ld->bsd", jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["dw1"])),
        p["dw2"])
    w = jnp.exp(-jnp.exp(w.astype(jnp.float32))).reshape(B, S, H, hd)

    state0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if cache is None
              else cache["wkv"])

    def step(s, inp):
        rt, kt, vt, wt = inp       # (B, H, hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32),
                        vt.astype(jnp.float32))
        out = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32),
                         s + p["u"][None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    # per-head group norm
    yh = y.reshape(B, S, H, hd).astype(jnp.float32)
    mu = jnp.mean(yh, -1, keepdims=True)
    var = jnp.var(yh, -1, keepdims=True)
    yh = ((yh - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, D)
    y = (yh.astype(x.dtype) * p["ln_x"]) * g.reshape(B, S, D)
    out = C.bsd(jnp.einsum("bse,ed->bsd", y, p["Wo"]))
    new_cache = None if cache is None else dict(
        shift_t=r0[:, -1, :], wkv=state)
    return x + out, new_cache


def channel_mix(x, p, cfg, cache=None):
    B, S, D = x.shape
    r0 = rms_norm(x, p["ln"], cfg.norm_eps)
    prev = (jnp.zeros((B, D), x.dtype) if cache is None
            else cache["shift_c"])
    sx = _token_shift(r0, prev) - r0
    xk = r0 + sx * p["mu_ck"]
    xr = r0 + sx * p["mu_cr"]
    k = jnp.einsum("bsd,df->bsf", xk, p["Wck"])
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("bsf,fd->bsd", k, p["Wcv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["Wcr"]))
    new_cache = None if cache is None else dict(shift_c=r0[:, -1, :])
    return x + C.bsd(r * v), new_cache


def rwkv_block(x, p, cfg, cache=None):
    """Full RWKV6 layer = time mix + channel mix."""
    x, c1 = time_mix(x, p, cfg, cache)
    x, c2 = channel_mix(x, p, cfg, cache)
    new_cache = None if cache is None else {**c1, **c2}
    return x, new_cache


def init_rwkv(key, cfg, dtype):
    rw, D, F = cfg.rwkv, cfg.d_model, cfg.d_ff
    hd = rw.head_dim
    H = D // hd
    L, M = rw.decay_lora, rw.mix_lora
    ks = jax.random.split(key, 12)
    std = D ** -0.5
    return dict(
        ln=jnp.ones((D,), dtype),
        mu_x=jnp.zeros((D,), dtype), mu_w=jnp.zeros((D,), dtype),
        mu_k=jnp.zeros((D,), dtype), mu_v=jnp.zeros((D,), dtype),
        mu_r=jnp.zeros((D,), dtype), mu_g=jnp.zeros((D,), dtype),
        mix_w1_p=jax.random.normal(ks[0], (5, D, M), dtype) * std,
        mix_w2=jax.random.normal(ks[1], (5, M, D), dtype) * M ** -0.5,
        Wr=jax.random.normal(ks[2], (D, D), dtype) * std,
        Wk=jax.random.normal(ks[3], (D, D), dtype) * std,
        Wv=jax.random.normal(ks[4], (D, D), dtype) * std,
        Wg=jax.random.normal(ks[5], (D, D), dtype) * std,
        Wo=jax.random.normal(ks[6], (D, D), dtype) * std,
        w0=jnp.full((D,), -1.0, dtype),
        dw1=jax.random.normal(ks[7], (D, L), dtype) * std,
        dw2=jax.random.normal(ks[8], (L, D), dtype) * L ** -0.5,
        u=jax.random.normal(ks[9], (H, hd), jnp.float32) * 0.1,
        ln_x=jnp.ones((D,), dtype),
        mu_ck=jnp.zeros((D,), dtype), mu_cr=jnp.zeros((D,), dtype),
        Wck=jax.random.normal(ks[10], (D, F), dtype) * std,
        Wcv=jax.random.normal(ks[11], (F, D), dtype) * F ** -0.5,
        Wcr=jax.random.normal(ks[0], (D, D), dtype) * std,
    )
