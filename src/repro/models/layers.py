"""Transformer building blocks: norms, rotary, GQA/SWA attention, SwiGLU.

Attention uses chunked online-softmax everywhere (never materializes the
full (Sq, Skv) score matrix) — the same algorithm as the Pallas flash
kernel; on TPU ops.attention dispatches to the kernel, on the dry-run
(XLA:CPU) this jnp path lowers with identical FLOPs and bounded memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding import constraints as C

NEG_INF = -1e30


def rms_norm(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rotary(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hf)
    cos = jnp.cos(ang)[..., :, None, :]                        # (..., S,1,hf)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _chunk_attention(q, k, v, *, causal: bool, window, q_offset,
                     kv_len=None, k_positions=None, chunk_q: int = 1024,
                     chunk_k: int = 2048, causal_prune: bool = True):
    """Flash-style online-softmax attention (q and kv both chunked).

    q: (B, Hq, Sq, d); k/v: (B, Hkv, Skv, d); kv repeats to Hq heads
    (see inline note — keeps the head dim 16-way shardable). Scores stay
    (B, Hq, cq, ck) per block; a full (Sq, Skv) matrix never exists.
    This is the jnp twin of kernels/flash_attn (the TPU dispatch target).

    q chunk i sits at absolute positions q_offset + i*chunk_q + [0, cq);
    kv_len (scalar) masks a partially filled cache; k_positions (Skv,)
    gives explicit absolute kv positions (ring-buffer caches; -1 = empty).

    causal_prune: when causal and q_offset is a static 0, q chunk i only
    visits kv chunks [0, ceil((i+1)*cq / ck)) — a static triangular
    schedule (per-q-chunk Python loop) that removes the ~2x masked-block
    waste of a rectangular scan while keeping all shapes static.
    """
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / (d ** 0.5)

    # GQA: repeat kv to Hq heads. NOT a memory bug under TP: kv heads
    # (4-8) don't divide the 16-way model axis and live replicated; the
    # repeated (B, Hq, S, d) IS 16-way head-shardable, so each device
    # slices its 2-4 heads locally (repeat-of-replicated = free). The
    # earlier (B, Hkv, G, S, d) grouped layout factored Hq as (8, 4),
    # which no single mesh axis can shard -> GSPMD replicated the whole
    # attention backward across all 16 model ranks (measured 2.5x total
    # train FLOPs on danube).
    if G > 1:
        k = jnp.repeat(k, G, axis=1)
        v = jnp.repeat(v, G, axis=1)

    if Sq <= 8:
        # decode fast path: one block over the full (sharded) kv. A
        # chunked scan would dynamic-slice the cache per step, which
        # GSPMD can only partition by all-gathering the whole cache
        # every decoded token (measured: 91% of decode collective
        # bytes). One einsum keeps kv sharded on Skv; the softmax
        # max/sum and the p@v partial-sum reduce over the sharded axis
        # as tiny (B,H,q) all-reduces — flash-decoding's math, GSPMD's
        # collectives.
        chunk_q, chunk_k = Sq, Skv
        if G > 1:
            # pin the repeated cache back to its sequence sharding —
            # GSPMD otherwise lowers the head-repeat of a seq-sharded
            # cache as a full gather (measured 33 MB/layer/token).
            b = C.batch_axes() or None
            k = C.constrain(k, b, None, C.TP, None)
            v = C.constrain(v, b, None, C.TP, None)

    cq = min(chunk_q, Sq)
    nq = (Sq + cq - 1) // cq
    ck = min(chunk_k, Skv)
    nk = (Skv + ck - 1) // ck
    pad_q = nq * cq - Sq
    pad_k = nk * ck - Skv

    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    qs = q.reshape(B, Hq, nq, cq, d).transpose(2, 0, 1, 3, 4)

    if k_positions is None:
        k_positions = jnp.arange(Skv, dtype=jnp.int32)
        if kv_len is not None:
            k_positions = jnp.where(k_positions < kv_len, k_positions, -1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad_k), constant_values=-1)
    ks = k.reshape(B, Hq, nk, ck, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, Hq, nk, ck, d).transpose(2, 0, 1, 3, 4)
    kp = k_positions.reshape(nk, ck)

    def kv_step(carry, xs, q_pos, qc):
        m_prev, l_prev, acc = carry
        kc, vc, kpc = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = kpc[None, :] >= 0
        if causal:
            mask = mask & (kpc[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (kpc[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(v.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_cur, l_cur, acc), None

    static_zero_offset = isinstance(q_offset, int) and q_offset == 0

    def one_q_chunk(i, qc, nk_i):
        q_pos = q_offset + i * cq + jnp.arange(cq, dtype=jnp.int32)
        init = (jnp.full((B, Hq, cq), NEG_INF, jnp.float32),
                jnp.zeros((B, Hq, cq), jnp.float32),
                jnp.zeros((B, Hq, cq, d), jnp.float32))
        step = functools.partial(kv_step, q_pos=q_pos, qc=qc)
        if nk_i == 1:   # no loop: keeps kv sharding visible to GSPMD
            (m, l, acc), _ = step(init, (ks[0], vs[0], kp[0]))
        else:
            (m, l, acc), _ = jax.lax.scan(
                step, init, (ks[:nk_i], vs[:nk_i], kp[:nk_i]))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    if nq == 1:
        out = one_q_chunk(0, qs[0], nk)[None]
    elif causal and causal_prune and static_zero_offset:
        # static triangular schedule: q chunk i sees kv chunks [0, lim_i)
        outs = []
        for i in range(nq):
            lim = min(nk, -(-((i + 1) * cq) // ck))
            outs.append(one_q_chunk(i, qs[i], lim))
        out = jnp.stack(outs)
    else:
        out = jax.lax.map(
            lambda args: one_q_chunk(args[0], args[1], nk),
            (jnp.arange(nq), qs))

    out = out.transpose(1, 2, 0, 3, 4).reshape(B, Hq, nq * cq, d)
    return out[:, :, :Sq]


def attention_block(x, p, cfg, positions, cache=None, cache_len=None,
                    cache_pos=None, causal: bool = True):
    """Full attention block (pre-norm, rotary, GQA, residual).

    x: (B, S, D). cache: None, or dict(k=(B, Hkv, W, hd), v=...) with
    cache_len = tokens already in the cache (scalar). When cache_pos
    (W,) int32 is given the cache is a *ring buffer* (W == cfg.window):
    new kv goes to slots (cache_len + i) % W and cache_pos holds each
    slot's absolute position (-1 = empty). Returns (x', new_kv_cache).
    """
    B, S, D = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    kk = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    vv = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        kk = kk + p["bk"]
        vv = vv + p["bv"]
    q = rotary(q, positions, cfg.rope_theta)
    kk = rotary(kk, positions, cfg.rope_theta)
    q = q.transpose(0, 2, 1, 3)    # (B, Hq, S, hd)
    kk = kk.transpose(0, 2, 1, 3)
    vv = vv.transpose(0, 2, 1, 3)
    # pin batch/head sharding: without these GSPMD may resolve the
    # FSDP weight-contraction conflict by replicating the batch
    # (sharding/constraints.py)
    b = C.batch_axes() or None
    q = C.constrain(q, b, C.TP, None, None)
    kk = C.constrain(kk, b, C.TP, None, None)
    vv = C.constrain(vv, b, C.TP, None, None)
    kw = dict(chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k,
              causal_prune=cfg.attn_causal_prune)

    if cache is None:
        out = _chunk_attention(q, kk, vv, causal=causal, window=cfg.window,
                               q_offset=0, **kw)
        new_cache = None
    elif cache_pos is not None:
        W = cache["k"].shape[2]
        if S >= W:
            # ring prefill (S >= window): attend over the in-flight
            # sequence directly; only the last W kv land in the cache.
            # (Assumes an empty ring — first prefill; chunked prefill
            # with chunks < W uses the scatter path below.)
            out = _chunk_attention(q, kk, vv, causal=causal,
                                   window=cfg.window, q_offset=cache_len,
                                   **kw)
            tail_pos = cache_len + S - W + jnp.arange(W, dtype=jnp.int32)
            slots = tail_pos % W
            ck = cache["k"].at[:, :, slots].set(
                kk[:, :, -W:].astype(cache["k"].dtype))
            cv = cache["v"].at[:, :, slots].set(
                vv[:, :, -W:].astype(cache["v"].dtype))
        else:
            slots = (cache_len + jnp.arange(S, dtype=jnp.int32)) % W
            ck = cache["k"].at[:, :, slots].set(kk.astype(cache["k"].dtype))
            cv = cache["v"].at[:, :, slots].set(vv.astype(cache["v"].dtype))
            new_pos = cache_pos.at[slots].set(
                cache_len + jnp.arange(S, dtype=jnp.int32))
            out = _chunk_attention(q, ck, cv, causal=causal,
                                   window=cfg.window, q_offset=cache_len,
                                   k_positions=new_pos, **kw)
        new_cache = dict(k=ck, v=cv)
    else:
        pos = cache_len
        ck = jax.lax.dynamic_update_slice(
            cache["k"], kk.astype(cache["k"].dtype), (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], vv.astype(cache["v"].dtype), (0, 0, pos, 0))
        out = _chunk_attention(q, ck, cv, causal=causal, window=cfg.window,
                               q_offset=pos, kv_len=pos + S, **kw)
        new_cache = dict(k=ck, v=cv)
    out = out.transpose(0, 2, 1, 3)  # (B, S, Hq, hd)
    y = C.bsd(jnp.einsum("bshk,hkd->bsd", out, p["wo"]))
    return x + y, new_cache


def swiglu_block(x, p, cfg):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    bx = C.batch_axes() or None
    a = C.constrain(jnp.einsum("bsd,df->bsf", h, p["w1"]), bx, None, C.TP)
    b = C.constrain(jnp.einsum("bsd,df->bsf", h, p["w3"]), bx, None, C.TP)
    y = C.bsd(jnp.einsum("bsf,fd->bsd", jax.nn.silu(a) * b, p["w2"]))
    return x + y


def init_attention(key, cfg, dtype):
    hd, Hq, Hkv, D = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    std = D ** -0.5
    p = dict(
        ln=jnp.ones((D,), dtype),
        wq=(jax.random.normal(ks[0], (D, Hq, hd), dtype) * std),
        wk=(jax.random.normal(ks[1], (D, Hkv, hd), dtype) * std),
        wv=(jax.random.normal(ks[2], (D, Hkv, hd), dtype) * std),
        wo=(jax.random.normal(ks[3], (Hq, hd, D), dtype)
            * (Hq * hd) ** -0.5),
    )
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq, hd), dtype)
        p["bk"] = jnp.zeros((Hkv, hd), dtype)
        p["bv"] = jnp.zeros((Hkv, hd), dtype)
    return p


def init_swiglu(key, cfg, dtype, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return dict(
        ln=jnp.ones((D,), dtype),
        w1=jax.random.normal(ks[0], (D, F), dtype) * D ** -0.5,
        w3=jax.random.normal(ks[1], (D, F), dtype) * D ** -0.5,
        w2=jax.random.normal(ks[2], (F, D), dtype) * F ** -0.5,
    )


def cross_attention_block(x, p, cfg, memory=None, mem_kv=None):
    """Cross-attention (decoder side of enc-dec): q from x, k/v from the
    encoder memory. No positional rotation (positions live in the encoder
    self-attention). mem_kv = precomputed (k, v) — during decode the
    encoder memory is static, so its projections are cached once.

    x: (B, S, D); memory: (B, Sm, D). Returns (x', (k, v))."""
    B, S, D = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"]).transpose(0, 2, 1, 3)
    if mem_kv is None:
        kk = jnp.einsum("bsd,dhk->bshk", memory,
                        p["wk"]).transpose(0, 2, 1, 3)
        vv = jnp.einsum("bsd,dhk->bshk", memory,
                        p["wv"]).transpose(0, 2, 1, 3)
    else:
        kk, vv = mem_kv
    out = _chunk_attention(q, kk, vv, causal=False, window=None,
                           q_offset=0, chunk_q=cfg.attn_chunk_q,
                           chunk_k=cfg.attn_chunk_k)
    out = out.transpose(0, 2, 1, 3)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return x + y, (kk, vv)


def init_cross_attention(key, cfg, dtype):
    """Cross-attention params (kv heads = q heads, standard for enc-dec)."""
    hd, Hq, D = cfg.hd, cfg.n_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    std = D ** -0.5
    return dict(
        ln=jnp.ones((D,), dtype),
        wq=jax.random.normal(ks[0], (D, Hq, hd), dtype) * std,
        wk=jax.random.normal(ks[1], (D, Hq, hd), dtype) * std,
        wv=jax.random.normal(ks[2], (D, Hq, hd), dtype) * std,
        wo=jax.random.normal(ks[3], (Hq, hd, D), dtype)
        * (Hq * hd) ** -0.5,
    )
