"""Decoder-LM assembly: pattern-grouped layers under lax.scan.

A model is cfg.n_layers layers, cycling cfg.pattern ('a'=attention,
'm'=mamba, 'r'=rwkv). Layers are grouped: one *group* = len(pattern)
consecutive layers; parameters of group position j are stacked over the
n_groups axis so the whole stack is one lax.scan (compile time and HLO
size stay flat even for 94-layer models). Within a group the positions
are unrolled statically, so heterogeneous mixers (jamba's 1:7
mamba/attention interleave) cost nothing.

Caches for serving share the same stacked layout; scan consumes the
per-group cache slice as xs and emits the updated slice as ys.

Ring-buffer KV caches (cfg.window set, capacity == window) make
long-context decode O(window) per step — why h2o-danube runs the
long_500k cell. See layers.attention_block for ring semantics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding import constraints as C

from . import layers, moe as moe_lib, rwkv as rwkv_lib, ssm as ssm_lib
from .config import ModelCfg


# ---------------------------------------------------------------- params

def _init_pos(key, cfg: ModelCfg, j: int, dtype):
    """Params for group position j (mixer + optional ffn)."""
    t = cfg.layer_type(j)
    km, kf = jax.random.split(key)
    if t == "a":
        p = {"mixer": layers.init_attention(km, cfg, dtype)}
    elif t == "m":
        p = {"mixer": ssm_lib.init_mamba(km, cfg, dtype)}
    elif t == "r":
        return {"mixer": rwkv_lib.init_rwkv(km, cfg, dtype)}
    else:
        raise ValueError(f"unknown layer type {t!r}")
    if cfg.is_moe_layer(j):
        p["ffn"] = moe_lib.init_moe(kf, cfg, dtype)
    else:
        p["ffn"] = layers.init_swiglu(kf, cfg, dtype)
    return p


def init_params(key, cfg: ModelCfg):
    """Returns the model pytree; group-position leaves have a leading
    (n_groups,) axis."""
    dtype = jnp.dtype(cfg.act_dtype)
    L = len(cfg.pattern)
    if cfg.moe is not None:
        assert L % cfg.moe.every == 0, \
            "moe.every must divide the pattern length for scanned groups"
    G = cfg.n_groups
    k_embed, k_head, k_groups, k_front = jax.random.split(key, 4)

    groups = {}
    for j in range(L):
        kj = jax.random.fold_in(k_groups, j)
        groups[f"pos{j}"] = jax.vmap(
            lambda k: _init_pos(k, cfg, j, dtype))(jax.random.split(kj, G))

    params = {
        "embed": jax.random.normal(
            k_embed, (cfg.vocab, cfg.d_model), dtype) * cfg.d_model ** -0.5,
        "final_ln": jnp.ones((cfg.d_model,), dtype),
        "groups": groups,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab), dtype) * cfg.d_model ** -0.5
    if cfg.frontend is not None:
        params["adapter"] = {
            "w": jax.random.normal(k_front, (cfg.frontend_dim, cfg.d_model),
                                   dtype) * cfg.frontend_dim ** -0.5,
            "b": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# --------------------------------------------------------------- forward

def _group_fn(x, gp, cfg: ModelCfg, positions):
    """One group of len(pattern) layers, training mode (no caches)."""
    x = C.bsd(x)          # re-gather the SP boundary (tiny AG)
    for j, t in enumerate(cfg.pattern):
        sub = gp[f"pos{j}"]
        if t == "a":
            x, _ = layers.attention_block(x, sub["mixer"], cfg, positions)
        elif t == "m":
            x, _ = ssm_lib.mamba_block(x, sub["mixer"], cfg)
        else:
            x, _ = rwkv_lib.rwkv_block(x, sub["mixer"], cfg)
            continue
        if cfg.is_moe_layer(j):
            x = moe_lib.moe_block(x, sub["ffn"], cfg)
        else:
            x = layers.swiglu_block(x, sub["ffn"], cfg)
    return C.sp_boundary(x)   # scan carry: S/tp per device (free slice)


_REMAT_POLICIES = {
    "dots": lambda: jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "full": lambda: None,
}


def _maybe_remat(fn, cfg: ModelCfg):
    if cfg.remat == "none":
        return fn
    policy = _REMAT_POLICIES[cfg.remat]()
    return jax.checkpoint(fn, policy=policy)


def _embed_inputs(params, tokens, cfg: ModelCfg, prefix_embed):
    x = C.bsd(jnp.take(params["embed"], tokens, axis=0))
    if prefix_embed is not None:
        pre = (prefix_embed.astype(x.dtype) @ params["adapter"]["w"]
               + params["adapter"]["b"])
        x = jnp.concatenate([pre, x], axis=1)
    return x


def forward_hidden(params, tokens, cfg: ModelCfg, prefix_embed=None):
    """tokens: (B, S_tok) int32; prefix_embed: (B, P, frontend_dim) or
    None. Returns final hidden states (B, P + S_tok, D)."""
    x = _embed_inputs(params, tokens, cfg, prefix_embed)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    body = _maybe_remat(
        lambda h, gp: (_group_fn(h, gp, cfg, positions), None), cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["groups"])
    else:
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            x, _ = body(x, gp)
    return layers.rms_norm(x, params["final_ln"], cfg.norm_eps)


def logits_fn(params, hidden, cfg: ModelCfg):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return jnp.einsum("bsd,dv->bsv", hidden, w)


def forward(params, tokens, cfg: ModelCfg, prefix_embed=None):
    """Full-vocab logits — test/small-model path (materializes (B,S,V))."""
    return logits_fn(params, forward_hidden(params, tokens, cfg,
                                            prefix_embed), cfg)


def loss_fn(params, tokens, labels, cfg: ModelCfg, prefix_embed=None):
    """Mean CE over label positions; logits computed in seq chunks of
    cfg.loss_chunk so (B, S, V) never materializes. labels: (B, S_tok),
    -1 = ignore. Loss covers the token suffix only (prefix positions are
    modality stubs)."""
    hidden = forward_hidden(params, tokens, cfg, prefix_embed)
    if prefix_embed is not None:
        hidden = hidden[:, prefix_embed.shape[1]:]
    B, S, D = hidden.shape
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])

    C = min(cfg.loss_chunk, S)
    n = (S + C - 1) // C
    pad = n * C - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(B, n, C, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, C).transpose(1, 0, 2)

    def chunk(carry, xs):
        h, lbl = xs
        logits = jnp.einsum("bsd,dv->bsv", h, w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lbl, 0)[..., None], axis=-1)[..., 0]
        valid = lbl >= 0
        nll = jnp.where(valid, lse - tgt, 0.0)
        tot, cnt = carry
        return (tot + jnp.sum(nll), cnt + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.float32(0), jnp.int32(0)),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1)


# ---------------------------------------------------------------- caches

def init_cache(cfg: ModelCfg, batch: int, max_len: int, dtype=None):
    """Decode cache pytree. Attention caches hold W = min(max_len, window)
    kv slots; ring layout iff windowed and window < max_len. Mamba/RWKV
    states are O(1) per token (why those archs run long_500k)."""
    dtype = dtype or jnp.dtype(cfg.act_dtype)
    G, L = cfg.n_groups, len(cfg.pattern)
    W = max_len if cfg.window is None else min(max_len, cfg.window)
    ring = W < max_len
    D = cfg.d_model
    layers_c = {}
    for j, t in enumerate(cfg.pattern):
        if t == "a":
            layers_c[f"pos{j}"] = dict(
                k=jnp.zeros((G, batch, cfg.n_kv_heads, W, cfg.hd), dtype),
                v=jnp.zeros((G, batch, cfg.n_kv_heads, W, cfg.hd), dtype))
        elif t == "m":
            di = cfg.ssm.expand * D
            layers_c[f"pos{j}"] = dict(
                conv=jnp.zeros((G, batch, di, cfg.ssm.d_conv - 1), dtype),
                h=jnp.zeros((G, batch, di, cfg.ssm.d_state), jnp.float32))
        else:
            H = D // cfg.rwkv.head_dim
            layers_c[f"pos{j}"] = dict(
                shift_t=jnp.zeros((G, batch, D), dtype),
                wkv=jnp.zeros((G, batch, H, cfg.rwkv.head_dim,
                               cfg.rwkv.head_dim), jnp.float32),
                shift_c=jnp.zeros((G, batch, D), dtype))
    cache = {"len": jnp.zeros((), jnp.int32), "layers": layers_c}
    if ring:
        cache["pos"] = jnp.full((W,), -1, jnp.int32)
    return cache


def forward_with_cache(params, cache, tokens, cfg: ModelCfg,
                       prefix_embed=None):
    """Shared prefill/decode forward. Returns (hidden, new_cache)."""
    x = _embed_inputs(params, tokens, cfg, prefix_embed)
    B, S, _ = x.shape
    L0 = cache["len"]
    ring_pos = cache.get("pos")
    positions = jnp.broadcast_to(L0 + jnp.arange(S, dtype=jnp.int32),
                                 (B, S))

    def body(x, xs):
        gp, gc = xs
        new_gc = {}
        for j, t in enumerate(cfg.pattern):
            sub, c = gp[f"pos{j}"], gc[f"pos{j}"]
            if t == "a":
                x, nc = layers.attention_block(
                    x, sub["mixer"], cfg, positions, cache=c,
                    cache_len=L0, cache_pos=ring_pos)
            elif t == "m":
                x, nc = ssm_lib.mamba_block(x, sub["mixer"], cfg, cache=c)
            else:
                x, nc = rwkv_lib.rwkv_block(x, sub["mixer"], cfg, cache=c)
            new_gc[f"pos{j}"] = nc
            if t != "r":
                if cfg.is_moe_layer(j):
                    x = moe_lib.moe_block(x, sub["ffn"], cfg)
                else:
                    x = layers.swiglu_block(x, sub["ffn"], cfg)
        return x, new_gc

    if cfg.scan_layers:
        x, new_layers = jax.lax.scan(
            body, x, (params["groups"], cache["layers"]))
    else:
        new_list = []
        for g in range(cfg.n_groups):
            sl = jax.tree.map(lambda a: a[g],
                              (params["groups"], cache["layers"]))
            x, ng = body(x, sl)
            new_list.append(ng)
        new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)

    new_cache = {"len": L0 + S, "layers": new_layers}
    if ring_pos is not None:
        W = ring_pos.shape[0]
        m = min(S, W)
        slots = (L0 + S - m + jnp.arange(m, dtype=jnp.int32)) % W
        new_cache["pos"] = ring_pos.at[slots].set(
            L0 + S - m + jnp.arange(m, dtype=jnp.int32))
    hidden = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    return hidden, new_cache


def prefill(params, tokens, cfg: ModelCfg, max_len: int,
            prefix_embed=None):
    """Run the prompt through the model, build the cache, return the
    last-position logits (B, 1, V) + cache ready for decode_step."""
    B = tokens.shape[0]
    cache = init_cache(cfg, B, max_len)
    hidden, cache = forward_with_cache(params, cache, tokens, cfg,
                                       prefix_embed)
    return logits_fn(params, hidden[:, -1:], cfg), cache


def decode_step(params, cache, tokens, cfg: ModelCfg):
    """One decode step. tokens: (B, 1). Returns (logits (B,1,V), cache)."""
    hidden, cache = forward_with_cache(params, cache, tokens, cfg)
    return logits_fn(params, hidden, cfg), cache
