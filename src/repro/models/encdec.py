"""Encoder-decoder assembly (seamless-m4t style).

Encoder: cfg.encoder_layers bidirectional attention layers over
precomputed modality-frontend embeddings (the assignment stubs the
speech frontend — ``input_specs()`` supplies frame embeddings).
Decoder: cfg.n_layers causal layers, each = self-attention +
cross-attention (over the encoder memory) + SwiGLU.

Both stacks use the same stacked-group lax.scan layout as
models/transformer.py. Decode caches the decoder self-attention kv AND
the cross-attention projections of the (static) encoder memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelCfg


# ---------------------------------------------------------------- params

def init_params(key, cfg: ModelCfg):
    dtype = jnp.dtype(cfg.act_dtype)
    ke, kd, kx, kemb, kfront = jax.random.split(key, 5)
    D = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"attn": layers.init_attention(k1, cfg, dtype),
                "ffn": layers.init_swiglu(k2, cfg, dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"attn": layers.init_attention(k1, cfg, dtype),
                "xattn": layers.init_cross_attention(k2, cfg, dtype),
                "ffn": layers.init_swiglu(k3, cfg, dtype)}

    return {
        "embed": jax.random.normal(kemb, (cfg.vocab, D), dtype) * D ** -0.5,
        "adapter": {
            "w": jax.random.normal(kfront, (cfg.frontend_dim, D), dtype)
            * cfg.frontend_dim ** -0.5,
            "b": jnp.zeros((D,), dtype),
        },
        "encoder": jax.vmap(enc_layer)(
            jax.random.split(ke, cfg.encoder_layers)),
        "enc_ln": jnp.ones((D,), dtype),
        "decoder": jax.vmap(dec_layer)(
            jax.random.split(kd, cfg.n_layers)),
        "final_ln": jnp.ones((D,), dtype),
    }


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# --------------------------------------------------------------- encoder

def encode(params, frames, cfg: ModelCfg):
    """frames: (B, S_enc, frontend_dim) -> memory (B, S_enc, D)."""
    x = (frames.astype(params["embed"].dtype) @ params["adapter"]["w"]
         + params["adapter"]["b"])
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, lp):
        h, _ = layers.attention_block(h, lp["attn"], cfg, positions,
                                      causal=False)
        h = layers.swiglu_block(h, lp["ffn"], cfg)
        return h, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.rms_norm(x, params["enc_ln"], cfg.norm_eps)


# --------------------------------------------------------------- decoder

def _dec_body(x, lp, cfg, positions, memory=None, mem_kv=None,
              cache=None, cache_len=None):
    x, kv = layers.attention_block(x, lp["attn"], cfg, positions,
                                   cache=cache, cache_len=cache_len)
    x, xkv = layers.cross_attention_block(x, lp["xattn"], cfg,
                                          memory=memory, mem_kv=mem_kv)
    x = layers.swiglu_block(x, lp["ffn"], cfg)
    return x, kv, xkv


def decode_train(params, tokens, memory, cfg: ModelCfg):
    """Teacher-forced decoder pass. tokens: (B, S_dec)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, lp):
        h, _, _ = _dec_body(h, lp, cfg, positions, memory=memory)
        return h, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    return layers.rms_norm(x, params["final_ln"], cfg.norm_eps)


def forward(params, frames, tokens, cfg: ModelCfg):
    """Full enc-dec forward to logits (B, S_dec, V)."""
    memory = encode(params, frames, cfg)
    hidden = decode_train(params, tokens, memory, cfg)
    return jnp.einsum("bsd,vd->bsv", hidden, params["embed"])


def loss_fn(params, frames, tokens, labels, cfg: ModelCfg):
    """Chunked CE like transformer.loss_fn (never (B,S,V))."""
    memory = encode(params, frames, cfg)
    hidden = decode_train(params, tokens, memory, cfg)
    B, S, D = hidden.shape
    C = min(cfg.loss_chunk, S)
    n = (S + C - 1) // C
    pad = n * C - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(B, n, C, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, C).transpose(1, 0, 2)

    def chunk(carry, xs):
        h, lbl = xs
        logits = jnp.einsum("bsd,vd->bsv", h,
                            params["embed"]).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lbl, 0)[..., None], axis=-1)[..., 0]
        valid = lbl >= 0
        tot, cnt = carry
        return (tot + jnp.sum(jnp.where(valid, lse - tgt, 0.0)),
                cnt + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.float32(0), jnp.int32(0)),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1)


# --------------------------------------------------------------- serving

def init_cache(cfg: ModelCfg, batch: int, max_len: int, mem_len: int,
               dtype=None):
    dtype = dtype or jnp.dtype(cfg.act_dtype)
    L, Hq, Hkv, hd = cfg.n_layers, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "len": jnp.zeros((), jnp.int32),
        "self_k": jnp.zeros((L, batch, Hkv, max_len, hd), dtype),
        "self_v": jnp.zeros((L, batch, Hkv, max_len, hd), dtype),
        "mem_k": jnp.zeros((L, batch, Hq, mem_len, hd), dtype),
        "mem_v": jnp.zeros((L, batch, Hq, mem_len, hd), dtype),
    }


def prefill(params, frames, tokens, cfg: ModelCfg, max_len: int):
    """Encode frames, prime both caches with the decoder prompt."""
    memory = encode(params, frames, cfg)
    B = tokens.shape[0]
    cache = init_cache(cfg, B, max_len, memory.shape[1])
    return _forward_cached(params, cache, tokens, cfg, memory=memory)


def decode_step(params, cache, tokens, cfg: ModelCfg):
    return _forward_cached(params, cache, tokens, cfg, memory=None)


def _forward_cached(params, cache, tokens, cfg: ModelCfg, memory=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    B, S, _ = x.shape
    L0 = cache["len"]
    positions = jnp.broadcast_to(L0 + jnp.arange(S, dtype=jnp.int32),
                                 (B, S))

    def body(h, xs):
        lp, sk, sv, mk, mv = xs
        mem_kv = None if memory is not None else (mk, mv)
        h, kv, xkv = _dec_body(h, lp, cfg, positions, memory=memory,
                               mem_kv=mem_kv, cache=dict(k=sk, v=sv),
                               cache_len=L0)
        nk, nv = (xkv if memory is not None else (mk, mv))
        return h, (kv["k"], kv["v"], nk, nv)

    x, (sk, sv, mk, mv) = jax.lax.scan(
        body, x, (params["decoder"], cache["self_k"], cache["self_v"],
                  cache["mem_k"], cache["mem_v"]))
    hidden = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", hidden[:, -1:], params["embed"])
    new_cache = {"len": L0 + S, "self_k": sk, "self_v": sv,
                 "mem_k": mk, "mem_v": mv}
    return logits, new_cache
