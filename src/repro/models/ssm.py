"""Mamba-1 selective-scan block (jamba's mixer), chunked associative scan.

Training: the recurrence h_t = a_t * h_{t-1} + b_t is associative; we scan
chunks sequentially (bounded memory) and use an associative scan inside a
chunk (parallel depth log C). Decode: O(1) state update (conv tail + h).
State shards with d_inner over the "model" axis (TP).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constraints as C

from .layers import rms_norm

SSM_CHUNK = 256


def _selective_scan(a, b, h0):
    """a, b: (B, S, di, ds) with h_t = a_t * h_{t-1} + b_t; h0: (B, di, ds).
    Returns all h_t (B, S, di, ds) and final h."""
    B, S, di, ds = a.shape
    chunk = min(SSM_CHUNK, S)
    n = (S + chunk - 1) // chunk
    pad = n * chunk - S
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
    a = a.reshape(B, n, chunk, di, ds).transpose(1, 0, 2, 3, 4)
    b = b.reshape(B, n, chunk, di, ds).transpose(1, 0, 2, 3, 4)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def per_chunk(h, ab):
        ac, bc = ab
        As, Bs = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = As * h[:, None] + Bs
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(per_chunk, h0, (a, b))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, n * chunk, di, ds)
    return hs[:, :S], h_last


def mamba_block(x, p, cfg, cache=None):
    """x: (B, S, D). cache: None or dict(conv=(B, di, K-1), h=(B, di, ds))."""
    ssm = cfg.ssm
    B, S, D = x.shape
    di = ssm.expand * D
    ds, K = ssm.d_state, ssm.d_conv
    dtr = ssm.dt_rank or max(1, D // 16)

    r = rms_norm(x, p["ln"], cfg.norm_eps)
    xz = C.constrain(jnp.einsum("bsd,de->bse", r, p["in_proj"]),
                     C.batch_axes() or None, None, C.TP)
    xi, z = jnp.split(xz, 2, axis=-1)              # (B, S, di)

    # causal depthwise conv
    xt = xi.transpose(0, 2, 1)                      # (B, di, S)
    if cache is None:
        tail = jnp.zeros((B, di, K - 1), xt.dtype)
    else:
        tail = cache["conv"]
    xt_full = jnp.concatenate([tail, xt], axis=-1)
    conv = sum(p["conv_w"][None, :, k: k + 1] * xt_full[:, :, k: k + S]
               for k in range(K))
    conv = conv + p["conv_b"][None, :, None]
    new_tail = xt_full[:, :, -(K - 1):]
    xc = jax.nn.silu(conv.transpose(0, 2, 1))       # (B, S, di)

    proj = jnp.einsum("bse,ef->bsf", xc, p["x_proj"])
    dt, Bm, Cm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt, p["dt_proj"])
                         + p["dt_bias"])            # (B, S, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))    # (di, ds)
    da = jnp.exp(dt[..., None].astype(jnp.float32) * A)       # (B,S,di,ds)
    db = (dt[..., None] * Bm[:, :, None, :] * xc[..., None]
          ).astype(jnp.float32)

    h0 = (jnp.zeros((B, di, ds), jnp.float32) if cache is None
          else cache["h"])
    hs, h_last = _selective_scan(da, db, h0)
    y = jnp.einsum("bsnk,bsk->bsn", hs, Cm.astype(jnp.float32))
    y = (y + xc.astype(jnp.float32) * p["D_skip"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = C.bsd(jnp.einsum("bse,ed->bsd", y, p["out_proj"]))
    new_cache = None if cache is None else dict(conv=new_tail, h=h_last)
    return x + out, new_cache


def init_mamba(key, cfg, dtype):
    ssm, D = cfg.ssm, cfg.d_model
    di = ssm.expand * D
    ds, K = ssm.d_state, ssm.d_conv
    dtr = ssm.dt_rank or max(1, D // 16)
    ks = jax.random.split(key, 6)
    return dict(
        ln=jnp.ones((D,), dtype),
        in_proj=jax.random.normal(ks[0], (D, 2 * di), dtype) * D ** -0.5,
        conv_w=jax.random.normal(ks[1], (di, K), dtype) * K ** -0.5,
        conv_b=jnp.zeros((di,), dtype),
        x_proj=jax.random.normal(ks[2], (di, dtr + 2 * ds), dtype)
        * di ** -0.5,
        dt_proj=jax.random.normal(ks[3], (dtr, di), dtype) * dtr ** -0.5,
        dt_bias=jnp.full((di,), -4.0, dtype),  # softplus(-4) ~ small dt
        A_log=jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        D_skip=jnp.ones((di,), jnp.float32),
        out_proj=jax.random.normal(ks[4], (di, D), dtype) * di ** -0.5,
    )
