"""Mixture-of-Experts with capacity-based sort dispatch (EP-friendly).

Two dispatch paths, numerically identical (tests/test_models.py):

* dense (`_moe_group`) — token-expert pairs ranked per expert; the
  first C survive; activations gathered into an (E, C, D) buffer and
  hit the expert matmuls as one batched einsum. Under pjit the
  cross-expert scatter/gather lowers to whatever GSPMD picks — on the
  production mesh it picks gather-all-reduces (measured: 27% of qwen3's
  train collective bytes, §Perf cell A).
* shard_map (`_moe_group_shard_map`, default when an ambient mesh with
  a "model" axis is set) — manual expert parallelism: each model rank
  owns E/tp experts, routes its replicated token block to *local*
  experts only (the sieve/bucket idea from the paper's sieve primitive:
  rank-within-bucket packing, fixed capacity), computes, and one psum
  over "model" combines the partial outputs. Comm per group = exactly
  one (Tg, D) all-reduce — no gathers, no scatters.

Tokens are processed in groups (cfg.moe_group) scanned sequentially so
the (E, C, D) buffer stays bounded (VMEM/HBM footprint knob for §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constraints as cstr

from .layers import rms_norm

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

P = jax.sharding.PartitionSpec


def _route(xg, wr, K):
    """Router: returns (topw (Tg,K) normalized, topi (Tg,K) int32)."""
    logits = jnp.einsum("td,de->te", xg, wr).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
    return topw.astype(xg.dtype), topi


def _rank_in_expert(flat_e, n_buckets):
    """Stable rank of each pair within its expert bucket (sieve-style)."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)
    inv = jnp.argsort(order).astype(jnp.int32)
    sorted_e = flat_e[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    change = jnp.concatenate([jnp.ones((1,), bool),
                              sorted_e[1:] != sorted_e[:-1]])
    first = jax.lax.associative_scan(jnp.maximum,
                                     jnp.where(change, idx, 0))
    return (idx - first)[inv]


def _expert_ffn(xe, p):
    h1 = jnp.einsum("ecd,edf->ecf", xe, p["w1"])
    h3 = jnp.einsum("ecd,edf->ecf", xe, p["w3"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(h1) * h3, p["w2"])


def _moe_group(xg, p, cfg, moe):
    """Dense-dispatch path. xg: (Tg, D) -> (Tg, D)."""
    Tg, D = xg.shape
    E, K = moe.n_experts, moe.top_k
    C = max(1, int(Tg * K * moe.capacity_factor / E))

    topw, topi = _route(xg, p["wr"], K)
    flat_e = topi.reshape(-1)                                # (Tg*K,)
    rank = _rank_in_expert(flat_e, E)
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)         # E*C => drop
    idx = jnp.arange(Tg * K, dtype=jnp.int32)
    tok = idx // K
    xe = jnp.zeros((E * C, D), xg.dtype).at[slot].set(
        xg[tok], mode="drop").reshape(E, C, D)
    ye = _expert_ffn(xe, p).reshape(E * C, D)
    safe = jnp.minimum(slot, E * C - 1)
    yk = jnp.where(keep[:, None], ye[safe], 0).reshape(Tg, K, D)
    return jnp.einsum("tk,tkd->td", topw, yk)


def _moe_shard_map(h, p, cfg, moe, mesh):
    """Manual-EP path (full-manual shard_map over every mesh axis):
    tokens stay on their data rank, experts live on their model rank,
    the router runs on local tokens, local experts compute, and ONE
    psum over "model" combines partial outputs. h: (B, S, D) with B
    sharded over the DP axes; returns y (B, S, D) likewise."""
    E, K = moe.n_experts, moe.top_k
    axes = dict(zip(mesh.axis_names,
                    mesh.shape.values() if hasattr(mesh.shape, "values")
                    else mesh.shape))
    tp = axes["model"]
    El = E // tp
    dp = tuple(a for a in ("pod", "data") if a in axes)

    def local(h, wr, w1, w3, w2):
        Bl, S, D = h.shape
        hf = h.reshape(-1, D)
        T = hf.shape[0]
        Tg = min(cfg.moe_group, T)
        n_groups = (T + Tg - 1) // Tg
        hf = jnp.pad(hf, ((0, n_groups * Tg - T), (0, 0)))
        r = jax.lax.axis_index("model")

        def one(xg):
            C = max(1, int(Tg * K * moe.capacity_factor / E))
            topw, topi = _route(xg, wr, K)
            flat_e = topi.reshape(-1)
            mine = (flat_e // El) == r
            el = jnp.where(mine, flat_e % El, El)     # El => foreign
            rank = _rank_in_expert(jnp.where(mine, flat_e, E), E)
            keep = mine & (rank < C)
            slot = jnp.where(keep, el * C + rank, El * C)
            idx = jnp.arange(Tg * K, dtype=jnp.int32)
            xe = jnp.zeros((El * C + 1, D), xg.dtype).at[slot].set(
                xg[idx // K], mode="drop")[:-1].reshape(El, C, D)
            ye = _expert_ffn(xe, dict(w1=w1, w3=w3, w2=w2)
                             ).reshape(El * C, D)
            safe = jnp.minimum(slot, El * C - 1)
            yk = jnp.where(keep[:, None], ye[safe], 0).reshape(Tg, K, D)
            return jnp.einsum("tk,tkd->td", topw, yk)  # local experts

        y = jax.lax.map(one, hf.reshape(n_groups, Tg, D))
        y = y.reshape(-1, D)[:T].reshape(Bl, S, D)
        return jax.lax.psum(y, "model")               # ONLY collective

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(dp or None, None, None), P(), P("model"),
                             P("model"), P("model")),
                   out_specs=P(dp or None, None, None), check_vma=False)
    return fn(h, p["wr"], p["w1"], p["w3"], p["w2"])


def moe_block(x, p, cfg):
    """x: (B, S, D), residual included."""
    B, S, D = x.shape
    moe = cfg.moe
    h = rms_norm(x, p["ln"], cfg.norm_eps)

    am = cstr._mesh()
    shape = dict(zip(am.axis_names,
                     am.shape.values() if hasattr(am.shape, "values")
                     else am.shape)) if am is not None else {}
    n_dp = 1
    for a in ("pod", "data"):
        n_dp *= shape.get(a, 1)
    # manual-EP pays one weight-reshard on shard_map entry; worth it
    # when many tokens amortize it (train/prefill), not for decode
    # (dense dispatch + GSPMD is near-free at B tokens/step).
    use_sm = (cfg.moe_shard_map and am is not None
              and "model" in shape
              and moe.n_experts % shape["model"] == 0
              and B % n_dp == 0
              and (B * S) // n_dp >= 512)
    if use_sm:
        y = _moe_shard_map(h, p, cfg, moe, am)
        return x + cstr.bsd(y)

    Tg = min(cfg.moe_group, B * S)
    hf = h.reshape(-1, D)
    T = hf.shape[0]
    n_groups = (T + Tg - 1) // Tg
    pad = n_groups * Tg - T
    hf = jnp.pad(hf, ((0, pad), (0, 0)))
    groups = hf.reshape(n_groups, Tg, D)
    y = jax.lax.map(lambda g: _moe_group(g, p, cfg, moe), groups)
    y = cstr.bsd(y.reshape(-1, D)[:T].reshape(B, S, D))
    return x + y


def init_moe(key, cfg, dtype):
    moe, D = cfg.moe, cfg.d_model
    E, F = moe.n_experts, moe.d_ff
    ks = jax.random.split(key, 4)
    return dict(
        ln=jnp.ones((D,), dtype),
        wr=jax.random.normal(ks[0], (D, E), dtype) * D ** -0.5,
        w1=jax.random.normal(ks[1], (E, D, F), dtype) * D ** -0.5,
        w3=jax.random.normal(ks[2], (E, D, F), dtype) * D ** -0.5,
        w2=jax.random.normal(ks[3], (E, F, D), dtype) * F ** -0.5,
    )
