"""Model configuration (architecture zoo)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int            # per-expert hidden
    every: int = 1       # MoE on layers where (i % every == every - 1)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:             # Mamba-1
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0      # 0 => d_model // 16


@dataclasses.dataclass(frozen=True)
class RWKVCfg:            # RWKV6 "Finch"
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    kind: str = "decoder"        # decoder | encdec
    encoder_layers: int = 0
    # per-layer pattern, cycled over layers: 'a'=attention, 'm'=mamba,
    # 'r'=rwkv. "a" = plain transformer; jamba = "mmmammmm".
    pattern: str = "a"
    rope_theta: float = 1e6
    qkv_bias: bool = False
    window: Optional[int] = None         # sliding-window attention
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    rwkv: Optional[RWKVCfg] = None
    frontend: Optional[str] = None       # None | 'audio' | 'vision'
    frontend_seq: int = 0                # stub embedding positions
    frontend_dim: int = 1024             # stub embedding feature dim
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    act_dtype: str = "bfloat16"
    # perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    attn_chunk_q: int = 1024
    attn_chunk_k: int = 2048
    attn_causal_prune: bool = True       # static triangular kv schedule
    moe_group: int = 4096
    moe_shard_map: bool = True           # manual-EP dispatch (§Perf A)
    loss_chunk: int = 1024               # CE computed in seq chunks
    remat: str = "dots"                  # none | dots | full
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group_pattern(self) -> str:
        return self.pattern

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.n_layers} layers not divisible by pattern {self.pattern}"
        return self.n_layers // len(self.pattern)

    def layer_type(self, i: int) -> str:
        return self.pattern[i % len(self.pattern)]

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every
                                         == self.moe.every - 1)

    def with_(self, **kw) -> "ModelCfg":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}
