"""Lint driver: file discovery, rule execution, pragma resolution, CLI.

Usage::

    PYTHONPATH=src python -m repro.analysis.lint src benchmarks examples

or, installed, ``repro-lint src``. Exit status 0 iff no unsuppressed
violations. Programmatic entry points:

* :func:`lint_sources` — lint a ``{path: source}`` mapping (what the
  fixture tests use; paths may be virtual);
* :func:`lint_paths` — discover ``*.py`` under files/directories and
  lint them.

Fixture files may carry a ``# lint-as: <virtual path>`` first-line
header so path-scoped rules (allowlists keyed on e.g.
``core/engine.py``) can be exercised from ``tests/fixtures/``.
"""

from __future__ import annotations

import os
import sys
from typing import Iterable, Mapping

from .diagnostics import Diagnostic, LintResult
from .pragmas import BAD_PRAGMA, UNUSED_PRAGMA, parse_pragmas
from .rules import RULE_NAMES, RULES
from .visitor import JitRegistry, LintContext, ModuleInfo, norm_path

LINT_AS_PREFIX = "# lint-as:"


def _effective_path(path: str, source: str) -> str:
    first = source.split("\n", 1)[0]
    if first.startswith(LINT_AS_PREFIX):
        return norm_path(first[len(LINT_AS_PREFIX):].strip())
    return norm_path(path)


def lint_sources(sources: Mapping[str, str]) -> LintResult:
    """Run every rule over the given ``{path: source}`` mapping."""
    result = LintResult()
    modules: list[ModuleInfo] = []
    for path, source in sorted(sources.items()):
        try:
            modules.append(ModuleInfo.parse(_effective_path(path, source),
                                            source))
        except SyntaxError as exc:
            result.diagnostics.append(Diagnostic(
                path=norm_path(path), line=exc.lineno or 1,
                col=(exc.offset or 1) - 1, rule="parse-error",
                message=f"could not parse: {exc.msg}"))
    result.files = len(sources)

    registry = JitRegistry()
    for mod in modules:
        registry.add_module(mod)
    ctx = LintContext(modules=modules, jit_registry=registry)

    rules = [cls() for cls in RULES]
    for mod in modules:
        raw = [d for rule in rules for d in rule.check(mod, ctx)]
        pragmas = parse_pragmas(mod.source)

        for diag in sorted(raw):
            hit = next((p for p in pragmas
                        if p.target == diag.line and p.rule == diag.rule),
                       None)
            if hit is not None:
                hit.used = True
                result.suppressed.append(diag)
            else:
                result.diagnostics.append(diag)

        # pragma hygiene — neither meta-rule is itself suppressible, so
        # deleting (or typo-ing) a pragma always surfaces in CI
        for p in pragmas:
            if p.rule not in RULE_NAMES:
                result.diagnostics.append(Diagnostic(
                    path=mod.path, line=p.line, col=0, rule=BAD_PRAGMA,
                    message=f"unknown rule `{p.rule}` in contract "
                    f"pragma (known: {', '.join(RULE_NAMES)})"))
            elif not p.used:
                result.diagnostics.append(Diagnostic(
                    path=mod.path, line=p.line, col=0,
                    rule=UNUSED_PRAGMA,
                    message=f"pragma allows `{p.rule}` but line "
                    f"{p.target} has no such violation; remove the "
                    f"stale pragma"))

    result.diagnostics.sort()
    result.suppressed.sort()
    return result


def discover(paths: Iterable[str]) -> list[str]:
    """Expand files/directories into a sorted list of ``*.py`` files."""
    out: list[str] = []
    for path in paths:
        if os.path.isfile(path):
            out.append(path)
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(d for d in dirs
                             if d != "__pycache__"
                             and not d.startswith("."))
            out.extend(os.path.join(root, f) for f in sorted(files)
                       if f.endswith(".py"))
    return sorted(set(out))


def lint_paths(paths: Iterable[str]) -> LintResult:
    sources: dict[str, str] = {}
    for path in discover(paths):
        with open(path, encoding="utf-8") as fh:
            sources[path] = fh.read()
    return lint_sources(sources)


def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--help" in argv or "-h" in argv:
        print(__doc__)
        print("rules:")
        for cls in RULES:
            print(f"  {cls.name:22s} {cls.description}")
        return 0
    paths = argv or ["src"]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"repro-lint: no such path: {', '.join(missing)}",
              file=sys.stderr)
        return 2
    result = lint_paths(paths)
    for diag in result.diagnostics:
        print(diag.render())
    print(f"repro-lint: {result.summary()}")
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
