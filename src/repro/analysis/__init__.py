"""``repro.analysis``: static enforcement of the repo's contracts.

The stack's invariants — unjitted ``_impl`` spellings inside shard_map
regions (the jax 0.4.37 nested-jit miscompile), exactness knobs owned by
the QueryEngine alone, capacity internals owned by the facade, snapshot
isolation vs. donation, a sync-free serving dispatch path, and
signature-cached jit closures — were documented prose until this
package. Now they are rules: a stdlib-``ast`` linter with per-rule
classes, file/line diagnostics, and ``# contract: allow[rule-name]``
suppression pragmas, run by CI (and ``tests/test_contracts.py``) over
``src/``.

Run it locally:

    PYTHONPATH=src python -m repro.analysis.lint src benchmarks examples

or via the ``repro-lint`` console script. See ROADMAP.md "Contracts"
for the rule list and the invariant each one guards.
"""

from .diagnostics import Diagnostic, LintResult
from .lint import lint_paths, lint_sources, main
from .rules import RULES

__all__ = ["Diagnostic", "LintResult", "RULES", "lint_paths",
           "lint_sources", "main"]
