"""Diagnostic records and the lint run result."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Diagnostic:
    """One violation at a file/line, attributed to a rule."""

    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.rule}] {self.message}")


@dataclasses.dataclass
class LintResult:
    """Outcome of one lint run over a set of files.

    ``diagnostics`` are the *unsuppressed* violations (nonempty => the
    run fails); ``suppressed`` are violations silenced by a
    ``# contract: allow[rule]`` pragma, kept so the CLI can report how
    many contract escapes the tree carries.
    """

    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)
    suppressed: list[Diagnostic] = dataclasses.field(default_factory=list)
    files: int = 0

    @property
    def ok(self) -> bool:
        return not self.diagnostics

    def summary(self) -> str:
        return (f"{len(self.diagnostics)} violation(s), "
                f"{len(self.suppressed)} suppressed, "
                f"{self.files} file(s) checked")
