"""Linter framework: parsed-module model, name resolution, jit registry.

The rules in :mod:`repro.analysis.rules` are syntactic, but several of
the contracts they guard are *cross-module* facts — "is the callee a
``jax.jit``-wrapped function?" depends on where the callee is defined.
This module gives rules the two pieces of shared infrastructure:

* :class:`ModuleInfo` — one parsed file plus its import-alias table, so
  a rule can resolve ``jnp.sum`` -> ``jax.numpy.sum`` or
  ``spac.insert`` -> ``spac.insert`` without executing anything; and
* :class:`JitRegistry` — a first pass over *all* linted files recording
  every function that is jit-wrapped at module level (``@jax.jit``,
  ``@functools.partial(jax.jit, ...)``, or ``name = jax.jit(fn)``), so
  the shard_map rule can flag a jitted callee invoked in another file's
  shard_map region.

Resolution is best-effort by design: a linter must never import the
code under analysis, so aliases are tracked per module and dotted names
are matched by (module stem, attribute) pairs. That is exact for this
repo's idiom (explicit module imports, ``_impl`` spellings) and fails
open — unresolvable names are simply not flagged.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterator

from .diagnostics import Diagnostic


def norm_path(path: str) -> str:
    return path.replace("\\", "/")


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


@dataclasses.dataclass
class ModuleInfo:
    """One parsed source file plus its name-resolution tables."""

    path: str                 # normalized, as given to the linter
    stem: str                 # module basename without .py
    tree: ast.Module
    source: str
    # local name -> dotted origin ("jnp" -> "jax.numpy",
    # "shard_map" -> "jax.experimental.shard_map.shard_map", ...)
    origins: dict[str, str] = dataclasses.field(default_factory=dict)

    @classmethod
    def parse(cls, path: str, source: str) -> "ModuleInfo":
        path = norm_path(path)
        stem = path.rsplit("/", 1)[-1].removesuffix(".py")
        info = cls(path=path, stem=stem, tree=ast.parse(source),
                   source=source)
        info._collect_imports()
        return info

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.origins[a.asname] = a.name
                    else:
                        # ``import jax.numpy`` binds the name ``jax``
                        head = a.name.split(".")[0]
                        self.origins[head] = head
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                for a in node.names:
                    local = a.asname or a.name
                    self.origins[local] = (f"{base}.{a.name}" if base
                                           else a.name)

    def resolve(self, node: ast.AST) -> str | None:
        """Fully-resolved dotted path of a Name/Attribute expression,
        with the leading component mapped through this module's
        imports. Returns None for anything that is not a plain dotted
        chain (calls, subscripts, ...)."""
        dotted = dotted_name(node)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        origin = self.origins.get(head)
        if origin is None:
            return dotted
        return f"{origin}.{rest}" if rest else origin

    def resolves_to(self, node: ast.AST, target: str) -> bool:
        """True if the expression resolves exactly to ``target`` (a
        dotted path like "jax.jit")."""
        return self.resolve(node) == target


def is_jax_jit(node: ast.AST, mod: ModuleInfo) -> bool:
    """Expression is the ``jax.jit`` transform itself."""
    return mod.resolves_to(node, "jax.jit")


def is_jit_wrapping(node: ast.AST, mod: ModuleInfo) -> bool:
    """Expression *applies* jax.jit: ``jax.jit(...)``,
    ``functools.partial(jax.jit, ...)``, or either used bare as a
    decorator."""
    if is_jax_jit(node, mod):
        return True
    if isinstance(node, ast.Call):
        if is_jax_jit(node.func, mod):
            return True
        # functools.partial(jax.jit, static_argnames=...)
        if mod.resolve(node.func) in ("functools.partial", "partial") \
                and node.args and is_jax_jit(node.args[0], mod):
            return True
    return False


class JitRegistry:
    """(module stem, function name) pairs known to be jit-wrapped at
    module level across every linted file."""

    def __init__(self) -> None:
        self._jitted: set[tuple[str, str]] = set()

    def add_module(self, mod: ModuleInfo) -> None:
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(is_jit_wrapping(d, mod) for d in node.decorator_list):
                    self._jitted.add((mod.stem, node.name))
            elif isinstance(node, ast.Assign):
                if isinstance(node.value, ast.Call) and \
                        is_jax_jit(node.value.func, mod):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            self._jitted.add((mod.stem, t.id))

    def is_jitted(self, mod: ModuleInfo, callee: ast.AST) -> str | None:
        """If ``callee`` (the func of a Call in ``mod``) resolves to a
        registered jitted function, return its dotted description."""
        resolved = mod.resolve(callee)
        if resolved is None:
            return None
        parts = resolved.split(".")
        if len(parts) == 1:
            # bare name: defined (or jit-assigned) in this module
            return resolved if (mod.stem, resolved) in self._jitted \
                else None
        # dotted: match by (module stem, attribute) — exact enough for
        # the repo's explicit-module-import idiom
        if (parts[-2], parts[-1]) in self._jitted:
            return resolved
        return None


@dataclasses.dataclass
class LintContext:
    """Shared state rules can consult: every parsed module plus the
    cross-file jit registry."""

    modules: list[ModuleInfo]
    jit_registry: JitRegistry


class Rule:
    """Base class: one named contract checked per module."""

    name: str = ""
    description: str = ""

    def check(self, mod: ModuleInfo,
              ctx: LintContext) -> Iterator[Diagnostic]:
        raise NotImplementedError

    def diag(self, mod: ModuleInfo, node: ast.AST,
             message: str) -> Diagnostic:
        return Diagnostic(path=mod.path, line=node.lineno,
                          col=node.col_offset, rule=self.name,
                          message=message)


def path_in(mod: ModuleInfo, suffixes: tuple[str, ...]) -> bool:
    return any(mod.path.endswith(s) for s in suffixes)
