"""``# contract: allow[rule-name]`` suppression pragmas.

A pragma silences exactly one rule on exactly one line:

* written as a trailing comment, it applies to its own line;
* written on a comment-only line, it applies to the next code line
  (pragmas stack: consecutive comment-line pragmas all target the same
  following code line) — needed when the flagged line has no room left
  at 79 columns.

Anything after the closing bracket is the human-facing justification
and is required by convention (the audit rule: every pragma says *why*
the violation is safe). Pragma hygiene is itself linted: an unknown
rule name raises ``bad-pragma`` and a pragma that suppresses nothing
raises ``unused-pragma`` — so a stale pragma can never silently
rubber-stamp future code. Neither meta-rule can be pragma'd away.
"""

from __future__ import annotations

import dataclasses
import io
import re
import tokenize

PRAGMA_RE = re.compile(r"#\s*contract:\s*allow\[([^\]\s]*)\]")

# meta-rules emitted by the pragma machinery itself
BAD_PRAGMA = "bad-pragma"
UNUSED_PRAGMA = "unused-pragma"


@dataclasses.dataclass
class Pragma:
    line: int           # line the pragma comment sits on (1-based)
    target: int         # code line it suppresses
    rule: str
    used: bool = False


def _is_comment_only(text: str) -> bool:
    stripped = text.lstrip()
    return stripped.startswith("#")


def _comment_lines(source: str) -> set[int]:
    """Line numbers that carry a real COMMENT token. Tokenizing (rather
    than regexing raw lines) keeps pragma-shaped text inside string
    literals and docstrings — e.g. this module's own docs — inert."""
    out: set[int] = set()
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out.add(tok.start[0])
    except (tokenize.TokenError, IndentationError):
        # fall back to treating every line as a candidate; the source
        # already parsed with ast, so this is close to unreachable
        out.update(range(1, source.count("\n") + 2))
    return out


def parse_pragmas(source: str) -> list[Pragma]:
    """Scan comments for pragmas and resolve each one's target line."""
    lines = source.splitlines()
    commented = _comment_lines(source)
    pragmas: list[Pragma] = []
    for i, text in enumerate(lines, start=1):
        if i not in commented:
            continue
        hits = PRAGMA_RE.findall(text)
        if not hits:
            continue
        if _is_comment_only(text):
            # applies to the next non-comment, non-blank line
            target = i
            for j in range(i + 1, len(lines) + 1):
                nxt = lines[j - 1]
                if nxt.strip() and not _is_comment_only(nxt):
                    target = j
                    break
        else:
            target = i
        pragmas.extend(Pragma(line=i, target=target, rule=r) for r in hits)
    return pragmas
