"""The contract rules. Each class enforces one invariant from
ROADMAP.md; see the "Contracts" section there for the narrative form,
and ``tests/fixtures/contracts/`` for a violating + clean example of
every rule.

Escape hatch: ``# contract: allow[rule-name] <why it is safe>`` on (or
immediately above) the flagged line — see :mod:`repro.analysis.pragmas`.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .diagnostics import Diagnostic
from .visitor import (JitRegistry, LintContext, ModuleInfo, Rule,
                      dotted_name, is_jax_jit, is_jit_wrapping, path_in)


def _last(dotted: str | None) -> str | None:
    return dotted.rsplit(".", 1)[-1] if dotted else None


def _const_str(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


# ---------------------------------------------------------------------------
# 1. jit-in-shard-map
# ---------------------------------------------------------------------------

class JitInShardMap(Rule):
    """jax 0.4.37 miscompiles a nested ``jax.jit`` (notably around
    while_loops) inside ``shard_map`` — wrong results on shards != 0.
    Shard-local code must call the unjitted ``_impl`` spellings;
    shard_map's own trace is the only jit the region gets."""

    name = "jit-in-shard-map"
    description = ("no jit-wrapped callable invoked inside a shard_map "
                   "region; use the unjitted _impl spellings")

    def check(self, mod: ModuleInfo,
              ctx: LintContext) -> Iterator[Diagnostic]:
        wrappers = self._wrapper_names(mod)
        seen: set[int] = set()
        yield from self._scan(mod.tree.body, mod, ctx, wrappers,
                              scopes=[], seen=seen)

    @staticmethod
    def _wrapper_names(mod: ModuleInfo) -> set[str]:
        """Local helpers that forward their first parameter into
        shard_map (e.g. a version-compat ``_smap``) open regions too."""
        out: set[str] = set()
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            params = [a.arg for a in fn.args.args]
            if not params:
                continue
            for node in ast.walk(fn):
                if (isinstance(node, ast.Call)
                        and _last(dotted_name(node.func)) == "shard_map"
                        and node.args
                        and isinstance(node.args[0], ast.Name)
                        and node.args[0].id == params[0]):
                    out.add(fn.name)
                    break
        return out

    def _scan(self, body, mod: ModuleInfo, ctx: LintContext,
              wrappers: set[str], scopes: list[dict], seen: set[int],
              ) -> Iterator[Diagnostic]:
        """Walk statement lists keeping a def-scope stack so a region
        named ``local`` resolves to the *enclosing function's* ``local``,
        not whichever same-named def happens to come last in the file."""
        scope = {n.name: n for n in body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        scopes = scopes + [scope]
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._scan(stmt.body, mod, ctx, wrappers,
                                      scopes, seen)
                continue
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call) or not node.args:
                    continue
                callee = _last(dotted_name(node.func))
                if callee != "shard_map" and callee not in wrappers:
                    continue
                region = node.args[0]
                if isinstance(region, ast.Name):
                    region = next(
                        (s[region.id] for s in reversed(scopes)
                         if region.id in s), None)
                if not isinstance(region, (ast.FunctionDef,
                                           ast.AsyncFunctionDef,
                                           ast.Lambda)):
                    continue
                if id(region) in seen:
                    continue
                seen.add(id(region))
                yield from self._check_region(region, mod,
                                              ctx.jit_registry)

    def _check_region(self, region, mod: ModuleInfo,
                      registry: JitRegistry) -> Iterator[Diagnostic]:
        for node in ast.walk(region):
            if not isinstance(node, ast.Call):
                continue
            if is_jit_wrapping(node, mod):
                yield self.diag(
                    mod, node, "jax.jit constructed inside a shard_map "
                    "region (nested jit miscompiles under shard_map on "
                    "jax 0.4.x)")
                continue
            jitted = registry.is_jitted(mod, node.func)
            if jitted is not None:
                yield self.diag(
                    mod, node,
                    f"call to jit-wrapped `{jitted}` inside a shard_map "
                    f"region; call its unjitted `_impl` spelling "
                    f"(nested jit miscompiles under shard_map on "
                    f"jax 0.4.x)")


# ---------------------------------------------------------------------------
# 2. exactness-knobs
# ---------------------------------------------------------------------------

class ExactnessKnobs(Rule):
    """Query answers are exact *because* only the QueryEngine sizes the
    fixed-capacity buffers and checks truncation. A caller passing
    ``max_rows=``/``cap=`` or reading ``truncated`` is re-opening the
    silent-short-answer hole PR 2 closed."""

    name = "exactness-knobs"
    description = ("no truncated reads or max_rows=/cap= keywords "
                   "outside the query engine layer")

    ALLOWED = ("core/engine.py", "core/queries.py")
    KNOBS = ("max_rows", "cap")

    def check(self, mod: ModuleInfo,
              ctx: LintContext) -> Iterator[Diagnostic]:
        if path_in(mod, self.ALLOWED):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and \
                    node.attr == "truncated":
                yield self.diag(
                    mod, node, "`truncated` read outside the engine "
                    "layer; the QueryEngine escalates buffers until "
                    "nothing truncates — use its exact surface")
            elif isinstance(node, ast.Call):
                if _last(dotted_name(node.func)) in ("getattr",
                                                     "hasattr") and \
                        len(node.args) >= 2 and \
                        _const_str(node.args[1]) == "truncated":
                    yield self.diag(
                        mod, node, "`truncated` read (via getattr/"
                        "hasattr) outside the engine layer")
                for kw in node.keywords:
                    if kw.arg in self.KNOBS:
                        yield self.diag(
                            mod, kw.value, f"`{kw.arg}=` passed outside "
                            f"the engine layer; exactness requires the "
                            f"QueryEngine to own buffer sizing")


# ---------------------------------------------------------------------------
# 3. capacity-internals
# ---------------------------------------------------------------------------

class CapacityInternals(Rule):
    """The facade's never-lose-points guarantee holds because only
    ``core/index.py`` drives the grow -> retry -> compact ladder and
    reads overflow flags. Outside callers touching capacity internals
    bypass that recovery (the serving runtime's deferred ``overflowed``
    read is the one sanctioned exception)."""

    name = "capacity-internals"
    description = ("no capacity_rows/overflowed/grow/compact access "
                   "outside the index facade and backend modules")

    BACKEND_FILES = ("core/index.py", "core/porth.py", "core/spac.py",
                     "core/baselines.py", "core/leafstore.py",
                     "core/distributed.py")
    # serving/server.py's deferred-overflow read is the sanctioned
    # exception (ROADMAP "Serving runtime": commit-time check + replay)
    ALLOWED = {
        "capacity_rows": BACKEND_FILES,
        "overflowed": BACKEND_FILES + ("serving/server.py",),
        "grow": BACKEND_FILES,
        "compact": BACKEND_FILES,
    }

    def check(self, mod: ModuleInfo,
              ctx: LintContext) -> Iterator[Diagnostic]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute):
                name = node.attr
                if name in ("capacity_rows", "overflowed"):
                    if not path_in(mod, self.ALLOWED[name]):
                        yield self.diag(
                            mod, node, f"`{name}` access outside the "
                            f"index facade; capacity is automatic — "
                            f"use make_index/insert and let the "
                            f"grow->retry->compact ladder recover")
            elif isinstance(node, ast.Call):
                callee = _last(dotted_name(node.func))
                if callee in ("grow", "compact") and \
                        isinstance(node.func, ast.Attribute) and \
                        not path_in(mod, self.ALLOWED[callee]):
                    yield self.diag(
                        mod, node, f"`{callee}()` called outside the "
                        f"index facade; the facade owns capacity "
                        f"recovery")
                elif _last(dotted_name(node.func)) in ("getattr",
                                                       "hasattr") and \
                        len(node.args) >= 2 and \
                        _const_str(node.args[1]) in ("capacity_rows",
                                                     "overflowed"):
                    name = _const_str(node.args[1])
                    if not path_in(mod, self.ALLOWED[name]):
                        yield self.diag(
                            mod, node, f"`{name}` access (via getattr/"
                            f"hasattr) outside the index facade")


# ---------------------------------------------------------------------------
# 4. donate-into-server
# ---------------------------------------------------------------------------

class DonateIntoServer(Rule):
    """Snapshot isolation needs old versions' buffers live;
    ``donate=True`` hands them to the next update. The server refuses
    such indexes at runtime — this rule catches it before it runs."""

    name = "donate-into-server"
    description = "no donate=True index may reach SpatialServer"

    def check(self, mod: ModuleInfo,
              ctx: LintContext) -> Iterator[Diagnostic]:
        for scope in self._scopes(mod.tree):
            donated = self._donated_names(scope)
            for node in self._shallow_walk(scope):
                if not isinstance(node, ast.Call):
                    continue
                dotted = dotted_name(node.func) or ""
                is_ctor = _last(dotted) == "SpatialServer"
                is_build = dotted.endswith("SpatialServer.build")
                if is_build and self._has_donate(node):
                    yield self.diag(
                        mod, node, "SpatialServer.build(donate=True): "
                        "snapshots need old buffers live; use the "
                        "version window for memory control")
                if not is_ctor:
                    continue
                for arg in list(node.args) + \
                        [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Name) and arg.id in donated:
                        yield self.diag(
                            mod, node, f"index `{arg.id}` was built "
                            f"with donate=True and flows into "
                            f"SpatialServer; snapshot isolation "
                            f"forbids donation")
                    elif isinstance(arg, ast.Call) and \
                            self._has_donate(arg):
                        yield self.diag(
                            mod, node, "donate=True index constructed "
                            "directly inside SpatialServer(...); "
                            "snapshot isolation forbids donation")

    @staticmethod
    def _scopes(tree: ast.Module):
        yield tree
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node

    @staticmethod
    def _shallow_walk(scope) -> Iterator[ast.AST]:
        """Walk one scope without descending into nested function
        scopes — a name donated in one function must not taint a
        same-named index in another."""
        stack = list(ast.iter_child_nodes(scope))
        while stack:
            node = stack.pop()
            yield node
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef, ast.Lambda)):
                stack.extend(ast.iter_child_nodes(node))

    @staticmethod
    def _has_donate(call: ast.Call) -> bool:
        for kw in call.keywords:
            if kw.arg == "donate" and isinstance(kw.value, ast.Constant) \
                    and bool(kw.value.value):
                return True
        return False

    def _donated_names(self, scope) -> set[str]:
        out: set[str] = set()
        for node in self._shallow_walk(scope):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    self._has_donate(node.value):
                out.update(t.id for t in node.targets
                           if isinstance(t, ast.Name))
        return out


# ---------------------------------------------------------------------------
# 5. host-sync-in-dispatch
# ---------------------------------------------------------------------------

class HostSyncInDispatch(Rule):
    """The serving contract is that ``insert``/``delete`` and request
    enqueue *dispatch and return* — any host read of a device value
    there (block_until_ready / .item() / np.asarray / float) silently
    serializes the pipeline and hides the async win the version window
    exists for."""

    name = "host-sync-in-dispatch"
    description = ("no device->host sync on the serving dispatch path "
                   "(server.insert/delete, batcher enqueue)")

    SCOPES = {
        "serving/server.py": ("insert", "delete", "_publish",
                              "_live_rows"),
        "serving/batcher.py": ("submit_knn", "submit_range_count",
                               "submit_range_list", "_enqueue",
                               "_as_rows"),
    }

    def check(self, mod: ModuleInfo,
              ctx: LintContext) -> Iterator[Diagnostic]:
        names = None
        for suffix, fns in self.SCOPES.items():
            if mod.path.endswith(suffix):
                names = fns
                break
        if names is None:
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef,
                                 ast.AsyncFunctionDef)) and \
                    node.name in names:
                yield from self._check_fn(node, mod)

    def _check_fn(self, fn, mod: ModuleInfo) -> Iterator[Diagnostic]:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = _last(dotted_name(node.func))
            if callee == "block_until_ready":
                yield self.diag(
                    mod, node, f"block_until_ready in dispatch-path "
                    f"`{fn.name}`: updates/enqueues must return "
                    f"without waiting on device work")
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                yield self.diag(
                    mod, node, f".item() in dispatch-path `{fn.name}` "
                    f"is a device sync")
            elif mod.resolve(node.func) == "numpy.asarray":
                yield self.diag(
                    mod, node, f"np.asarray in dispatch-path "
                    f"`{fn.name}` pulls device values to host; defer "
                    f"the read to a sync point (commit)")
            elif isinstance(node.func, ast.Name) and \
                    node.func.id == "float" and node.args and \
                    not isinstance(node.args[0], ast.Constant):
                yield self.diag(
                    mod, node, f"float() on a runtime value in "
                    f"dispatch-path `{fn.name}` is a device sync when "
                    f"the value lives on device")


# ---------------------------------------------------------------------------
# 6. uncached-jit
# ---------------------------------------------------------------------------

class UncachedJit(Rule):
    """A ``jax.jit`` constructed per call (inside a function body or a
    loop) gets a fresh trace cache each time — every invocation
    recompiles. Construct jits at module level, or behind a
    ``functools.lru_cache`` closure factory keyed on the static
    signature (the ``_update_closure`` / query-plan pattern)."""

    name = "uncached-jit"
    description = ("no jax.jit constructed per call; use module level "
                   "or an lru_cache closure factory")

    CACHE_DECOS = ("functools.lru_cache", "functools.cache",
                   "lru_cache", "cache")

    def check(self, mod: ModuleInfo,
              ctx: LintContext) -> Iterator[Diagnostic]:
        yield from self._visit(mod.tree.body, mod, depth=0,
                               cached=False, in_loop=False)

    def _is_cached(self, fn, mod: ModuleInfo) -> bool:
        for d in fn.decorator_list:
            target = d.func if isinstance(d, ast.Call) else d
            if mod.resolve(target) in self.CACHE_DECOS:
                return True
        return False

    def _visit(self, nodes, mod, *, depth: int, cached: bool,
               in_loop: bool) -> Iterator[Diagnostic]:
        for node in nodes:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # a jit decorator on a *nested* def is a per-call jit
                if depth > 0 and not cached and any(
                        is_jit_wrapping(d, mod)
                        for d in node.decorator_list):
                    yield self.diag(
                        mod, node, f"@jax.jit on nested def "
                        f"`{node.name}` re-traces per enclosing call; "
                        f"hoist to module level or an lru_cache "
                        f"closure factory")
                # recurse into the body only — decorators are handled
                # above and must not be re-flagged as plain jit calls
                yield from self._visit(
                    node.body, mod, depth=depth + 1,
                    cached=cached or self._is_cached(node, mod),
                    in_loop=False)
            elif isinstance(node, ast.Lambda):
                yield from self._visit(
                    [node.body], mod, depth=depth + 1, cached=cached,
                    in_loop=False)
            else:
                here_loop = in_loop or isinstance(
                    node, (ast.For, ast.While, ast.AsyncFor))
                if isinstance(node, ast.Call) and \
                        is_jax_jit(node.func, mod) and not cached and \
                        (depth > 0 or here_loop):
                    where = "a loop" if here_loop and depth == 0 else \
                        "a function body"
                    yield self.diag(
                        mod, node, f"jax.jit constructed inside "
                        f"{where} builds a fresh trace cache per call; "
                        f"hoist to module level or an lru_cache "
                        f"closure factory")
                yield from self._visit(
                    ast.iter_child_nodes(node), mod, depth=depth,
                    cached=cached, in_loop=here_loop)


# ---------------------------------------------------------------------------
# 7. obs-deferred-sync
# ---------------------------------------------------------------------------

class ObsDeferredSync(Rule):
    """``repro.obs`` promises that instrumenting a dispatch path adds
    no host syncs: device values are *attached* (``Span.defer`` /
    ``Recorder.add_deferred``) and read only in ``Recorder.resolve``,
    which callers invoke at an existing barrier. A stray
    ``block_until_ready`` / ``.item()`` / ``device_get`` / host
    ``asarray`` anywhere else in the package would silently reintroduce
    the sync the subsystem exists to avoid.

    Phase 2 extends the same promise to the accounting modules:
    ``obs/memory.py`` works from ``nbytes`` metadata (pure shape/dtype
    arithmetic) and ``obs/costs.py`` from AOT ``lower().compile()``
    artifacts — neither may call ``memory_stats()`` (a runtime query of
    the device allocator), which is sanctioned only inside
    ``Recorder.resolve``."""

    name = "obs-deferred-sync"
    description = ("repro.obs reads device values only inside "
                   "Recorder.resolve (the sanctioned barrier drain)")

    PACKAGE = "repro/obs/"
    SANCTIONED = ("resolve",)

    def check(self, mod: ModuleInfo,
              ctx: LintContext) -> Iterator[Diagnostic]:
        if self.PACKAGE not in mod.path.replace("\\", "/"):
            return
        yield from self._visit(mod.tree.body, mod)

    def _visit(self, body, mod: ModuleInfo) -> Iterator[Diagnostic]:
        stack = list(body)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.name not in self.SANCTIONED:
                    stack.extend(node.body)
                continue
            if isinstance(node, ast.Call):
                yield from self._check_call(node, mod)
            stack.extend(ast.iter_child_nodes(node))

    def _check_call(self, node: ast.Call,
                    mod: ModuleInfo) -> Iterator[Diagnostic]:
        callee = _last(dotted_name(node.func))
        if callee == "block_until_ready":
            yield self.diag(
                mod, node, "block_until_ready outside Recorder.resolve; "
                "attach the value (Span.defer / add_deferred) and let "
                "the barrier drain read it")
        elif isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and not node.args:
            yield self.diag(
                mod, node, ".item() outside Recorder.resolve is a "
                "device sync; defer the read to the barrier drain")
        elif callee == "device_get":
            yield self.diag(
                mod, node, "device_get outside Recorder.resolve; defer "
                "the read to the barrier drain")
        elif callee == "memory_stats":
            yield self.diag(
                mod, node, "memory_stats() outside Recorder.resolve "
                "queries the device allocator mid-dispatch; memory "
                "accounting uses nbytes metadata (repro.obs.memory), "
                "allocator snapshots belong in the barrier drain")
        elif mod.resolve(node.func) == "numpy.asarray":
            yield self.diag(
                mod, node, "np.asarray outside Recorder.resolve pulls "
                "device values to host; defer the read to the barrier "
                "drain")


RULES: tuple[type[Rule], ...] = (
    JitInShardMap, ExactnessKnobs, CapacityInternals, DonateIntoServer,
    HostSyncInDispatch, UncachedJit, ObsDeferredSync)

RULE_NAMES: tuple[str, ...] = tuple(r.name for r in RULES)
