"""AdamW + cosine schedule + global-norm clipping (hand-rolled, no optax).

Optimizer moments are float32 regardless of param dtype (mixed-precision
training: bf16 params/activations, f32 master statistics). State layout
is a pytree mirroring params, so ZeRO-1 sharding is just a spec tree
(sharding.rules.zero1_specs).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(step, cfg: OptCfg):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params, moment_dtype=jnp.float32):
    """moment_dtype=bf16 halves optimizer HBM — required to fit 398B-class
    models on a single 256-chip v5e pod (EXPERIMENTS.md §Dry-run)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: OptCfg):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_lr(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * u).astype(p.dtype),
                m32.astype(m.dtype), v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
