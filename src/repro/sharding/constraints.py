"""Activation sharding constraints (MaxText-style logical axes).

Why: FSDP puts the "data" axis on weight contraction dims. Without
activation pins, GSPMD may resolve the x@W ambiguity the wrong way —
replicate the *batch* across data ranks and partial-sum the output
(measured: 16x attention FLOPs + TB-scale gather all-reduces on the
train cells). Pinning activations to batch-sharded forces the intended
FSDP resolution: gather the (small) weight shard, keep tokens sharded.

All helpers no-op when no ambient mesh is set (single-device tests) and
silently drop axes that don't exist or don't divide — the same model
code runs everywhere. Launchers call :func:`set_ambient_mesh` (dryrun
does it per cell), which spells ``jax.sharding.set_mesh`` on jax >= 0.5
and falls back to the thread-resources mesh context on jax 0.4.x,
where ``get_abstract_mesh``/``set_mesh`` don't exist yet.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

TP = "model"
BATCH_AXES = ("pod", "data")


def _abstract_mesh():
    """jax.sharding.get_abstract_mesh, shimmed for jax 0.4.x (where the
    ambient mesh lives in the thread-resources env instead)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax._src import mesh as mesh_lib
    m = getattr(mesh_lib.thread_resources.env, "physical_mesh", None)
    return None if m is None or m.empty else m


def set_ambient_mesh(mesh):
    """Make ``mesh`` ambient for :func:`constrain` (version-portable
    spelling of ``jax.sharding.set_mesh``). Process-lifetime: launcher
    use only."""
    if hasattr(jax.sharding, "set_mesh"):
        jax.sharding.set_mesh(mesh)
    else:  # jax 0.4.x: hold the Mesh context open for the process
        mesh.__enter__()


def _mesh():
    am = _abstract_mesh()
    if am is None or not am.axis_names:
        return None
    return am


def constrain(x, *spec):
    """with_sharding_constraint that validates axes against the ambient
    mesh and dim divisibility; returns x unchanged when impossible."""
    am = _mesh()
    if am is None:
        return x
    shape = dict(zip(am.axis_names, am.shape.values())) \
        if hasattr(am.shape, "values") else dict(am.shape)
    clean = []
    for i, s in enumerate(spec):
        if s is None:
            clean.append(None)
            continue
        parts = tuple(p for p in (s if isinstance(s, tuple) else (s,))
                      if p in shape)
        n = 1
        for p in parts:
            n *= shape[p]
        if parts and n > 0 and x.shape[i] % n == 0:
            clean.append(parts if len(parts) > 1 else parts[0])
        else:
            clean.append(None)
    return jax.lax.with_sharding_constraint(x, P(*clean))


def batch_axes():
    am = _mesh()
    if am is None:
        return ()
    return tuple(a for a in BATCH_AXES if a in am.axis_names)


def bsd(x):
    """(batch, seq, d_model) activations: batch over DP axes."""
    return constrain(x, batch_axes() or None, None, None)


def sp_boundary(x):
    """Sequence-parallel layer-group boundary: (batch, S/tp, D).

    The lax.scan carry at group boundaries is exactly what remat saves;
    sharding its sequence dim over "model" cuts saved-activation HBM by
    tp (enabling 4-8x fewer microbatches, which scales down the
    per-microbatch gradient reduce traffic by the same factor). Exit is
    a comm-free local slice; re-entry is a (B*S*D/tp)-operand
    all-gather — ~1/tp of the all-reduce it stands next to."""
    return constrain(x, batch_axes() or None, TP, None)


def bshd(x, head_axis=TP):
    """(batch, seq|heads, heads|seq, hd): pin batch + heads."""
    return constrain(x, batch_axes() or None, None, head_axis, None)
