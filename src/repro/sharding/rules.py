"""Partition rules: params / optimizer state / inputs / caches -> PartitionSpec.

Spec trees are built by *mirroring the init_params structure* (not by
name-matching leaf paths), so they are correct by construction for every
arch in the zoo.

Axis roles (DESIGN.md Sec. 5):
  * batch    -> ("pod", "data")   pure DP; "pod" only exists multi-pod
  * TP       -> "model"           attention heads, ffn hidden, vocab
  * EP       -> "model"           experts (MoE layers)
  * SP       -> "model"           kv-cache sequence dim for decode
  * ZeRO-1   -> "data"            optimizer state, largest replicated dim

Small tensors (norms, biases, routers, rwkv loras) replicate — sharding
them buys nothing and costs collectives.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelCfg

TP = "model"


def _stack(tree):
    """Prepend the group-stack axis (None) to every spec leaf."""
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), tree,
        is_leaf=lambda x: isinstance(x, P))


def _divisible(n: int, mesh, axis: str) -> bool:
    return axis in mesh.shape and n % mesh.shape[axis] == 0


def spec_attention(cfg: ModelCfg, mesh):
    kv = P(None, TP, None) if _divisible(cfg.n_kv_heads, mesh, TP) \
        else P(None, None, None)
    kvb = P(TP, None) if _divisible(cfg.n_kv_heads, mesh, TP) \
        else P(None, None)
    s = dict(ln=P(None), wq=P(None, TP, None), wk=kv, wv=kv,
             wo=P(TP, None, None))
    if cfg.qkv_bias:
        s.update(bq=P(TP, None), bk=kvb, bv=kvb)
    return s


def spec_cross_attention(cfg: ModelCfg, mesh):
    return dict(ln=P(None), wq=P(None, TP, None), wk=P(None, TP, None),
                wv=P(None, TP, None), wo=P(TP, None, None))


def spec_swiglu():
    return dict(ln=P(None), w1=P(None, TP), w3=P(None, TP),
                w2=P(TP, None))


def spec_moe():
    # experts over "model" = expert parallelism; router replicated
    return dict(ln=P(None), wr=P(None, None), w1=P(TP, None, None),
                w3=P(TP, None, None), w2=P(TP, None, None))


def spec_mamba():
    # d_inner over "model" (TP); tiny projections replicated
    return dict(ln=P(None), in_proj=P(None, TP), conv_w=P(TP, None),
                conv_b=P(TP), x_proj=P(TP, None), dt_proj=P(None, TP),
                dt_bias=P(TP), A_log=P(TP, None), D_skip=P(TP),
                out_proj=P(TP, None))


def spec_rwkv(cfg: ModelCfg, mesh):
    H = cfg.d_model // cfg.rwkv.head_dim
    rep = P(None)
    return dict(
        ln=rep, mu_x=rep, mu_w=rep, mu_k=rep, mu_v=rep, mu_r=rep, mu_g=rep,
        mix_w1_p=P(None, None, None), mix_w2=P(None, None, None),
        Wr=P(None, TP), Wk=P(None, TP), Wv=P(None, TP), Wg=P(None, TP),
        Wo=P(TP, None), w0=rep, dw1=P(None, None), dw2=P(None, None),
        u=P(TP, None) if _divisible(H, mesh, TP) else P(None, None),
        ln_x=rep, mu_ck=rep, mu_cr=rep,
        Wck=P(None, TP), Wcv=P(TP, None), Wcr=P(None, TP))


def _spec_pos(cfg: ModelCfg, j: int, mesh):
    t = cfg.layer_type(j)
    if t == "a":
        s = {"mixer": spec_attention(cfg, mesh)}
    elif t == "m":
        s = {"mixer": spec_mamba()}
    else:
        return {"mixer": spec_rwkv(cfg, mesh)}
    s["ffn"] = spec_moe() if cfg.is_moe_layer(j) else spec_swiglu()
    return s


def param_specs(cfg: ModelCfg, mesh, fsdp: bool = True,
                mode: str = "train"):
    """Spec pytree matching transformer.init_params(cfg) exactly.

    fsdp=True additionally shards each *large* weight over the "data"
    axis on its first unsharded divisible dim (ZeRO-3 / FSDP: GSPMD
    all-gathers the shard just-in-time for each matmul and re-gathers
    in the backward under remat). Without it a 398B model is 50GB/chip
    on a 16-way TP axis — far over v5e HBM; with it, params scale with
    the whole pod (796GB/256 = 3.1GB/chip for jamba).

    mode="serve": weights must be *resident* — an FSDP re-gather per
    decoded token costs ~(params/tp) x (dp-1) wire bytes per step,
    ~90 ms/token for a 35B model (§Perf cell B). When TP-only fits
    comfortably in HBM (<= ~11 GiB/chip) serving drops the data-axis
    sharding entirely; bigger models keep the 2D layout (per-step comm
    then scales with the tiny decode activations, not the weights)."""
    if mode == "serve" and fsdp:
        n_par = sum(x.size for x in jax.tree.leaves(_param_shapes(cfg)))
        tp = mesh.shape.get(TP, 1)
        # MoE keeps the 2D layout regardless: expert matmuls contract
        # D over "data" — dropping it replicates expert compute across
        # the data axis (measured 7x compute on phi3.5).
        fsdp = (n_par * 2 / tp) > 11 * 2**30 or cfg.moe is not None
    if cfg.kind == "encdec":
        specs = encdec_param_specs(cfg, mesh)
    else:
        vocab = P(TP, None) if _divisible(cfg.vocab, mesh, TP) \
            else P(None, None)
        specs = {
            "embed": vocab,
            "final_ln": P(None),
            "groups": {f"pos{j}": _stack(_spec_pos(cfg, j, mesh))
                       for j in range(len(cfg.pattern))},
        }
        if not cfg.tie_embeddings:
            specs["unembed"] = P(None, TP)
        if cfg.frontend is not None:
            specs["adapter"] = {"w": P(None, None), "b": P(None)}
    if fsdp:
        shapes = _param_shapes(cfg)
        # FSDP only on the layer stacks: the embedding table must keep a
        # pure vocab sharding — a gather from a 2D-sharded table forces
        # GSPMD into "involuntary full rematerialization" (replicates
        # the table); layer weights are matmul operands and partition
        # cleanly.
        for k in ("groups", "encoder", "decoder"):
            if k in specs:
                specs[k] = _fsdp_augment(specs[k], shapes[k], mesh)
        if "unembed" in specs:
            specs["unembed"] = _fsdp_augment(
                specs["unembed"], shapes["unembed"], mesh)
    return specs


_FSDP_MIN = 1 << 20   # don't bother sharding leaves under 1M elements


def _param_shapes(cfg: ModelCfg):
    from repro.models import encdec, transformer
    init = (encdec.init_params if cfg.kind == "encdec"
            else transformer.init_params)
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


def _fsdp_augment(pspecs, shapes, mesh):
    dsize = mesh.shape.get("data", 1)

    def one(spec, shape):
        if dsize == 1 or shape.size < _FSDP_MIN:
            return spec
        parts = list(tuple(spec) + (None,) * (len(shape.shape) - len(spec)))
        # ndim>=3 leaves are group-stacked: never shard the scan axis
        # (a sharded xs axis would collective on every scan step)
        start = 1 if len(shape.shape) >= 3 else 0
        for i in range(start, len(parts)):
            if parts[i] is None and shape.shape[i] % dsize == 0 \
                    and shape.shape[i] >= dsize:
                parts[i] = "data"
                break
        return P(*parts)

    return jax.tree.map(one, pspecs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def encdec_param_specs(cfg: ModelCfg, mesh):
    vocab = P(TP, None) if _divisible(cfg.vocab, mesh, TP) else P(None, None)
    enc = _stack({"attn": spec_attention(cfg, mesh), "ffn": spec_swiglu()})
    dec = _stack({"attn": spec_attention(cfg, mesh),
                  "xattn": spec_cross_attention(cfg, mesh),
                  "ffn": spec_swiglu()})
    return {
        "embed": vocab,
        "adapter": {"w": P(None, None), "b": P(None)},
        "encoder": enc, "enc_ln": P(None),
        "decoder": dec, "final_ln": P(None),
    }


# ------------------------------------------------------------- optimizer

def zero1_specs(pspecs, shapes, mesh):
    """ZeRO-1: add "data" sharding to the first axis that is unsharded
    and divisible by the data-axis size (optimizer m/v/ef tensors)."""
    dsize = mesh.shape.get("data", 1)

    def one(spec, shape):
        flat = tuple(a for part in spec if part is not None
                     for a in (part if isinstance(part, tuple) else (part,)))
        if dsize == 1 or "data" in flat:
            return spec          # FSDP already shards this leaf over data
        parts = list(tuple(spec) + (None,) * (len(shape.shape) - len(spec)))
        start = 1 if len(shape.shape) >= 3 else 0   # skip the scan axis
        for i in range(start, len(parts)):
            if parts[i] is None and shape.shape[i] % dsize == 0 \
                    and shape.shape[i] >= dsize:
                parts[i] = "data"
                break
        return P(*parts)

    return jax.tree.map(one, pspecs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------- inputs

def batch_axes(mesh):
    """The pure-DP axes for the global batch dim."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_specs(mesh, global_batch: int):
    """tokens/labels (B, S) and prefix embeddings (B, P, F)."""
    dp = batch_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    b = dp if global_batch % n_dp == 0 else None
    return dict(tokens=P(b, None), labels=P(b, None),
                prefix=P(b, None, None))


def cache_specs(cfg: ModelCfg, mesh, batch: int):
    """Decode-cache spec tree matching transformer.init_cache.

    KV cache: batch over DP axes; sequence dim over "model" (SP — the
    long-context axis). When the batch cannot shard (long_500k b=1) the
    sequence dim also takes the idle "data" axis, so a 500k-token cache
    spreads over the whole pod.
    """
    dp = batch_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    b = dp if batch % n_dp == 0 else None
    seq = (TP,) if b is not None else tuple(dp) + (TP,)
    layers_c = {}
    for j, t in enumerate(cfg.pattern):
        if t == "a":
            kv = P(None, b, None, seq, None)
            layers_c[f"pos{j}"] = dict(k=kv, v=kv)
        elif t == "m":
            layers_c[f"pos{j}"] = dict(conv=P(None, b, TP, None),
                                       h=P(None, b, TP, None))
        else:
            H = cfg.d_model // cfg.rwkv.head_dim
            wkv_h = TP if H % mesh.shape.get(TP, 1) == 0 else None
            layers_c[f"pos{j}"] = dict(
                shift_t=P(None, b, None),
                wkv=P(None, b, wkv_h, None, None),
                shift_c=P(None, b, None))
    spec = {"len": P(), "layers": layers_c}
    if cfg.window is not None:
        spec["pos"] = P(None)   # ring slot table: tiny, replicated
    return spec


def encdec_cache_specs(cfg: ModelCfg, mesh, batch: int):
    dp = batch_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    b = dp if batch % n_dp == 0 else None
    seq = (TP,) if b is not None else tuple(dp) + (TP,)
    kv = P(None, b, None, seq, None)
    return {"len": P(), "self_k": kv, "self_v": kv,
            "mem_k": kv, "mem_v": kv}
