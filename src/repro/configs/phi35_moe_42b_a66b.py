"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE on every layer.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064.
"""

from repro.models.config import ModelCfg, MoECfg

CFG = ModelCfg(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab=32064, head_dim=128,
    moe=MoECfg(n_experts=16, top_k=2, d_ff=6400, every=1),
)
