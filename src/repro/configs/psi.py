"""The paper's own workload configurations (Sec. 5 experiment grid).

These drive benchmarks/ and the examples; sizes default to this
container's single CPU core and scale with --n / --full flags
(the paper's machine ran n = 1e9 on 112 cores).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PsiWorkload:
    name: str
    dist: str              # uniform | sweepline | varden
    n: int                 # index size
    dim: int = 2
    batch_ratios: tuple = (0.1, 0.01)     # incremental update ratios
    n_queries: int = 500
    knn_k: int = 10
    range_side_frac: float = 1 / 64       # of the coordinate domain
    phi: int = 32                          # leaf wrap (paper: 32-40)


# Fig. 3 grid (2D synthetic); paper: n=1e9, ratios 10%..0.01%
FIG3 = tuple(
    PsiWorkload(f"fig3-{d}", d, n=50_000) for d in
    ("uniform", "sweepline", "varden"))

# Fig. 9 grid (3D synthetic); paper: coordinates in [0, 1e6]
FIG9 = tuple(
    PsiWorkload(f"fig9-{d}", d, n=30_000, dim=3) for d in
    ("uniform", "varden"))

# Fig. 10 single-batch sweep; paper: batches 1e5..1e9 on n=1e9
FIG10 = PsiWorkload("fig10-uniform", "uniform", n=100_000,
                    batch_ratios=(0.001, 0.01, 0.1))

# dynamic service (examples/dynamic_index_serving.py)
SERVICE = PsiWorkload("service", "uniform", n=200_000,
                      batch_ratios=(0.025,))
