"""rwkv6-3b "Finch" — attention-free, data-dependent decay linear
attention. [arXiv:2404.05892; hf]

32L d_model=2560 d_ff=8960 vocab=65536. Time-mix state is
(H, 64, 64)/layer => O(1) decode; runs long_500k natively. n_heads /
n_kv_heads are placeholders (no attention layers exist).
"""

from repro.models.config import ModelCfg, RWKVCfg

CFG = ModelCfg(
    name="rwkv6-3b",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536,
    pattern="r",
    rwkv=RWKVCfg(head_dim=64, decay_lora=64, mix_lora=32),
)
