"""Platform/env staging: the ONE place that sets jax platform env vars.

jax reads ``XLA_FLAGS`` / ``JAX_PLATFORMS`` / ``JAX_ENABLE_X64`` when the
backend first initializes (the first ``jax.devices()`` / array op — *not*
at import), and the resulting device topology is locked for the process.
Code that needs a forced topology therefore has exactly two options:
stage the env vars before anything initializes the backend, or start a
fresh process. Historically each call site mutated ``os.environ``
directly (``launch/dryrun.py`` clobbered a user's ``XLA_FLAGS`` outright;
every distributed test pasted its own prelude) — this module replaces
all of them:

* :func:`stage` — idempotent env staging that *composes* with an
  existing ``XLA_FLAGS`` (other flags survive; stale spellings of the
  same flag are replaced). Raises if the backend already initialized
  with a conflicting topology, and no-ops when the env already matches.
* :func:`simulate_mesh` — CI's entry point: stage ``n`` forced host
  devices, initialize jax, and return a 1-D device mesh over them. An
  8-device CPU mesh exercises the full shard_map exchange
  (all_to_all/all_gather/psum routing) on a laptop or CI runner; see
  tests/helpers.py ``run_on_simulated_mesh`` for the subprocess fixture
  that guarantees the early-import requirement.

Keep this module light: importing it must not initialize (or require)
jax — :func:`stage` is pure env-var bookkeeping until something asks
for devices.
"""

from __future__ import annotations

import os
import sys

HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def jax_initialized() -> bool:
    """True once any jax backend has been created (topology locked).

    Checks the backend cache of an *already imported* jax — importing
    jax here would defeat the whole point of env staging."""
    bridge = sys.modules.get("jax._src.xla_bridge")
    if bridge is None:
        return False
    return bool(getattr(bridge, "_backends", None))


def _merge_xla_flags(new_flags: dict[str, str],
                     existing: str | None = None) -> str:
    """Compose ``new_flags`` ({"--flag": "value"}) into an existing
    ``XLA_FLAGS`` string: unrelated user flags survive, stale spellings
    of a staged flag are replaced (never duplicated)."""
    if existing is None:
        existing = os.environ.get("XLA_FLAGS", "")
    kept = [tok for tok in existing.split()
            if tok.split("=", 1)[0] not in new_flags]
    kept.extend(f"{flag}={val}" for flag, val in new_flags.items())
    return " ".join(kept)


def staged_host_device_count() -> int | None:
    """The forced host device count currently in ``XLA_FLAGS`` (None if
    not staged)."""
    for tok in os.environ.get("XLA_FLAGS", "").split():
        name, _, val = tok.partition("=")
        if name == HOST_DEVICE_FLAG and val:
            try:
                return int(val)
            except ValueError:
                return None
    return None


def stage(*, host_device_count: int | None = None,
          platform: str | None = None,
          enable_x64: bool | None = None) -> None:
    """Stage platform env vars; must run before jax initializes.

    Composes with (never clobbers) an existing ``XLA_FLAGS``. Safe to
    call repeatedly, and a no-op when the requested config is already
    in effect — so library entry points (``launch/dryrun``, the driver's
    ``--mesh`` flag) can call it unconditionally. Raises ``RuntimeError``
    when jax already initialized with a *conflicting* topology: the
    caller must stage earlier (or run in a subprocess — see
    tests/helpers.py)."""
    if host_device_count is not None:
        already = staged_host_device_count() == int(host_device_count)
        if jax_initialized() and not already:
            import jax  # already imported (jax_initialized saw it)
            have = len(jax.devices())
            if have != int(host_device_count):
                raise RuntimeError(
                    f"jax already initialized with {have} device(s); "
                    f"cannot force host_device_count="
                    f"{host_device_count} now. Stage the platform "
                    f"before the first jax.devices()/array op "
                    f"(import repro.configs.platform first), or run "
                    f"in a fresh process "
                    f"(tests/helpers.py:run_on_simulated_mesh).")
        if not already:
            os.environ["XLA_FLAGS"] = _merge_xla_flags(
                {HOST_DEVICE_FLAG: str(int(host_device_count))})
    if platform is not None:
        if jax_initialized() and \
                os.environ.get("JAX_PLATFORMS", "") != platform:
            raise RuntimeError(
                f"jax already initialized; cannot switch platform to "
                f"{platform!r} now")
        os.environ["JAX_PLATFORMS"] = platform
    if enable_x64 is not None:
        want = "1" if enable_x64 else "0"
        if jax_initialized() and \
                os.environ.get("JAX_ENABLE_X64") != want:
            raise RuntimeError(
                "jax already initialized; cannot toggle x64 now")
        os.environ["JAX_ENABLE_X64"] = want


def simulate_mesh(n: int, axis_names: tuple[str, ...] = ("data",)):
    """Stage ``n`` forced host devices, initialize jax, and return a
    1-D ``Mesh`` over the first ``n`` devices (CI's simulated pod).

    Must be the first jax-touching call of the process (the subprocess
    fixture in tests/helpers.py guarantees this for tests; the serving
    driver's ``--mesh N`` flag calls it before building anything)."""
    stage(host_device_count=n)
    import jax
    import numpy as np
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"simulate_mesh({n}): only {len(devs)} device(s) visible — "
            f"the forced host device count was staged after jax "
            f"initialized. Call simulate_mesh (or stage) before any "
            f"jax.devices()/array op, or use "
            f"tests/helpers.py:run_on_simulated_mesh.")
    return jax.sharding.Mesh(np.asarray(devs[:n]), axis_names)
