"""seamless-m4t-large-v2 — encoder-decoder, multimodal (speech frontend
stubbed: input_specs() provides precomputed frame embeddings).
[arXiv:2308.11596; hf]

24L encoder + 24L decoder, d_model=1024 16H (kv=16) d_ff=8192
vocab=256206.
"""

from repro.models.config import ModelCfg

CFG = ModelCfg(
    name="seamless-m4t-large-v2",
    kind="encdec", encoder_layers=24,
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    frontend="audio", frontend_dim=1024,
)
