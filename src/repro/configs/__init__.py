"""Architecture registry: the 10 assigned archs + smoke reductions.

``ARCHS[arch_id]`` is the exact published config; ``smoke(arch_id)`` is a
reduced same-family config for CPU tests (small width, few experts, tiny
vocab) — the full configs are only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation).

``cells(arch_id)`` lists the applicable input-shape cells:
long_500k needs sub-quadratic attention (runs for ssm/hybrid/SWA archs,
skipped for pure full-attention archs — DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses

from repro.models.config import SHAPES, ModelCfg, ShapeCfg  # noqa: F401

from . import (command_r_35b, h2o_danube_18b, internvl2_26b,
               jamba_1_5_large_398b, phi35_moe_42b_a66b, qwen3_moe_235b_a22b,
               qwen15_05b, rwkv6_3b, seamless_m4t_large_v2, yi_9b)

ARCHS: dict[str, ModelCfg] = {
    m.CFG.name: m.CFG
    for m in (jamba_1_5_large_398b, qwen3_moe_235b_a22b, phi35_moe_42b_a66b,
              rwkv6_3b, h2o_danube_18b, command_r_35b, yi_9b, qwen15_05b,
              seamless_m4t_large_v2, internvl2_26b)
}

# archs with sub-quadratic attention (SSM / hybrid / sliding-window)
LONG_OK = {"jamba-1.5-large-398b", "rwkv6-3b", "h2o-danube-1.8b"}


def cells(arch_id: str) -> list[str]:
    """Applicable shape cells for this arch (assignment skip rules)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_id in LONG_OK:
        names.append("long_500k")
    return names


def smoke(arch_id: str) -> ModelCfg:
    """Reduced same-family config: 1-2 groups, tiny width/vocab/experts."""
    cfg = ARCHS[arch_id]
    kw = dict(
        n_layers=len(cfg.pattern) * min(2, cfg.n_groups),
        d_model=128, n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads
        else 4, head_dim=32, d_ff=256, vocab=512,
        attn_chunk_q=64, attn_chunk_k=64, moe_group=256, loss_chunk=128,
    )
    if cfg.n_kv_heads == cfg.n_heads:
        kw["n_heads"] = kw["n_kv_heads"] = 4
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2,
                                        d_ff=256)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=4)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32,
                                         decay_lora=16, mix_lora=8)
        kw["n_heads"] = kw["n_kv_heads"] = 4
    if cfg.window is not None:
        kw["window"] = 32
    if cfg.kind == "encdec":
        kw["encoder_layers"] = 2
    if cfg.frontend is not None:
        kw["frontend_seq"] = 8
        kw["frontend_dim"] = 32
    return cfg.with_(**kw)
