"""command-r-35b — dense GQA, no biases.
[hf:CohereForAI/c4ai-command-r-v01; unverified]

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000. (The HF model
uses a parallel attn+FFN block; we keep the sequential residual layout
shared by the zoo — FLOP-identical, noted in DESIGN.md.)
"""

from repro.models.config import ModelCfg

CFG = ModelCfg(
    name="command-r-35b",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000, head_dim=128,
)
