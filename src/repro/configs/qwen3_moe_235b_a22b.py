"""qwen3-moe-235b-a22b — 128-expert top-8 MoE on every layer (no dense
FFN). [hf:Qwen/Qwen3-30B-A3B scaled per assignment; hf]

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936.
head_dim=128 (explicit in the Qwen3 config, so Hq*hd != d_model).
"""

from repro.models.config import ModelCfg, MoECfg

CFG = ModelCfg(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=128,
    moe=MoECfg(n_experts=128, top_k=8, d_ff=1536, every=1),
)
