"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e
top-2 on every other layer. [arXiv:2403.19887; hf]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536. Pattern period 8
with the attention layer at position 3 (Jamba block layout); MoE every 2.
Mamba state is O(1)/token => runs the long_500k cell.
"""

from repro.models.config import ModelCfg, MoECfg, SSMCfg

CFG = ModelCfg(
    name="jamba-1.5-large-398b",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, head_dim=128,
    pattern="mmmammmm",
    moe=MoECfg(n_experts=16, top_k=2, d_ff=24576, every=2),
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2),
)
