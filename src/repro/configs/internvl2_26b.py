"""internvl2-26b — VLM backbone (InternLM2-20B side); the InternViT
frontend is a stub (input_specs() provides precomputed patch
embeddings, 256 positions of dim 3200 after pixel-shuffle).
[arXiv:2404.16821; hf]

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
"""

from repro.models.config import ModelCfg

CFG = ModelCfg(
    name="internvl2-26b",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553, head_dim=128,
    frontend="vision", frontend_seq=256, frontend_dim=3200,
)
