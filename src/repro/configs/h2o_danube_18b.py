"""h2o-danube-1.8b — dense llama+mistral mix with sliding-window
attention. [arXiv:2401.16818; hf]

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096
=> sub-quadratic attention => runs long_500k (ring KV cache of 4096).
"""

from repro.models.config import ModelCfg

CFG = ModelCfg(
    name="h2o-danube-1.8b",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000, head_dim=80,
    window=4096,
)
