"""Latency/throughput accounting for the serving runtime.

The workload driver (:mod:`repro.serving.driver`) cares about the
*distribution* of per-op latency — a service SLO is a p99, not a mean —
so this module keeps raw per-op samples and reduces them to
p50/p95/p99 (plus mean/min/max) only at report time. Wall-clock
throughput (sustained q/s, update-points/s) is tracked separately so a
pipelined run is credited for overlap: op latencies can sum to more
than the wall window when updates hide behind queries.
"""

from __future__ import annotations

import contextlib
import time
from collections import defaultdict

import numpy as np

PERCENTILES = (50.0, 95.0, 99.0)


def summarize(samples_s) -> dict:
    """Reduce one op's latency samples (seconds) to a stats dict (ms)."""
    a = np.asarray(sorted(samples_s), dtype=np.float64) * 1e3
    out = {"count": int(a.size)}
    if not a.size:
        return out
    for p in PERCENTILES:
        out[f"p{p:g}_ms"] = float(np.percentile(a, p))
    out["mean_ms"] = float(a.mean())
    out["min_ms"] = float(a[0])
    out["max_ms"] = float(a[-1])
    return out


class LatencyRecorder:
    """Per-op latency samples + wall-window op counters.

    ``record`` during the measured window only — the driver runs its
    warmup reps against a recorder that is then :meth:`reset`, so
    jit compiles and the query engine's pow2 bucket-escalation retraces
    (see ``repro.core.engine``) never land in a percentile.
    """

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self.reset()

    def reset(self) -> None:
        self._samples: dict[str, list[float]] = defaultdict(list)
        self._counts: dict[str, int] = defaultdict(int)
        self._t0 = self._clock()

    def record(self, op: str, seconds: float, units: int = 1) -> None:
        """One latency sample for ``op``; ``units`` feeds throughput
        (e.g. points in an update batch, requests in a query flush)."""
        self._samples[op].append(float(seconds))
        self._counts[op] += int(units)

    @contextlib.contextmanager
    def timer(self, op: str, units: int = 1):
        t0 = self._clock()
        yield
        self.record(op, self._clock() - t0, units)

    @property
    def wall_s(self) -> float:
        return self._clock() - self._t0

    def count(self, op: str) -> int:
        return self._counts[op]

    def latency_summary(self) -> dict[str, dict]:
        """{op: {p50_ms, p95_ms, p99_ms, mean_ms, min_ms, max_ms,
        count}} over the measured window."""
        return {op: summarize(s) for op, s in sorted(self._samples.items())}

    def throughput(self, ops) -> dict[str, float]:
        """Sustained units/s per op over the shared wall window (ops
        overlap on device, so these are *service* rates, not inverse
        latencies)."""
        wall = max(self.wall_s, 1e-9)
        return {op: self._counts[op] / wall for op in ops}
