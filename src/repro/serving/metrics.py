"""Latency/throughput accounting for the serving runtime.

The workload driver (:mod:`repro.serving.driver`) cares about the
*distribution* of per-op latency — a service SLO is a p99, not a mean —
so this module keeps raw per-op samples and reduces them to
p50/p95/p99 (plus mean/min/max) only at report time. Wall-clock
throughput (sustained q/s, update-points/s) is tracked separately so a
pipelined run is credited for overlap: op latencies can sum to more
than the wall window when updates hide behind queries.

Since PR 7 the samples live in :class:`repro.obs.Hist` histograms
(under the ``lat.`` prefix) instead of a private list-per-op — pass the
driver's installed :class:`repro.obs.Recorder` and the percentiles, the
library's own counters/spans, and the exported trace all come from one
sink; with no recorder the class owns a private one and behaves exactly
as before.
"""

from __future__ import annotations

import contextlib
import time
from collections import defaultdict

import numpy as np

from .. import obs

PERCENTILES = (50.0, 95.0, 99.0)

#: histogram-name prefix LatencyRecorder claims inside a shared Recorder
LAT_PREFIX = "lat."


def summarize(samples_s) -> dict:
    """Reduce one op's latency samples (seconds) to a stats dict (ms)."""
    a = np.asarray(sorted(samples_s), dtype=np.float64) * 1e3
    out = {"count": int(a.size)}
    if not a.size:
        return out
    for p in PERCENTILES:
        out[f"p{p:g}_ms"] = float(np.percentile(a, p))
    out["mean_ms"] = float(a.mean())
    out["min_ms"] = float(a[0])
    out["max_ms"] = float(a[-1])
    return out


class LatencyRecorder:
    """Per-op latency samples + wall-window op counters, backed by
    :class:`repro.obs.Recorder` histograms.

    ``record`` during the measured window only — the driver runs its
    warmup reps against a recorder that is then :meth:`reset`, so
    jit compiles and the query engine's pow2 bucket-escalation retraces
    (see ``repro.core.engine``) never land in a percentile. ``reset``
    drops only the ``lat.`` histograms: a shared recorder's own
    counters/spans (plan-cache traffic, commit stalls, ...) keep
    accumulating across it, which is what trace export wants.
    """

    def __init__(self, clock=None, recorder: obs.Recorder | None = None):
        if recorder is not None:
            self._rec = recorder
            self._clock = clock if clock is not None else recorder.clock
        else:
            self._clock = clock if clock is not None else time.perf_counter
            # private sink: no timeline events, just the lat. histograms
            self._rec = obs.Recorder(clock=self._clock, keep_events=False)
        self.reset()

    @property
    def recorder(self) -> obs.Recorder:
        """The backing obs recorder (shared or private)."""
        return self._rec

    def reset(self) -> None:
        self._rec.drop(LAT_PREFIX)
        self._counts: dict[str, int] = defaultdict(int)
        self._t0 = self._clock()

    def record(self, op: str, seconds: float, units: int = 1,
               start: float | None = None) -> None:
        """One latency sample for ``op``; ``units`` feeds throughput
        (e.g. points in an update batch, requests in a query flush).
        Pass ``start`` (the sample's begin time on this recorder's
        clock) to also place the section on the exported timeline."""
        self._rec.observe(LAT_PREFIX + op, float(seconds))
        if start is not None:
            self._rec.add_span(LAT_PREFIX + op, start, float(seconds),
                               cat="latency", units=int(units))
        self._counts[op] += int(units)

    @contextlib.contextmanager
    def timer(self, op: str, units: int = 1):
        t0 = self._clock()
        yield
        self.record(op, self._clock() - t0, units, start=t0)

    @property
    def wall_s(self) -> float:
        return self._clock() - self._t0

    def count(self, op: str) -> int:
        return self._counts[op]

    def samples(self, op: str) -> list[float]:
        """Retained raw samples (seconds) for ``op``."""
        h = self._rec.hist(LAT_PREFIX + op)
        return list(h.samples) if h is not None else []

    def latency_summary(self) -> dict[str, dict]:
        """{op: {p50_ms, p95_ms, p99_ms, mean_ms, min_ms, max_ms,
        count}} over the measured window."""
        out = {}
        for name in sorted(self._rec.hists):
            if not name.startswith(LAT_PREFIX):
                continue
            h = self._rec.hists[name]
            # exact per-sample reduction while retention holds (the
            # driver's bounded windows), pow2-bucket fallback past it
            if h.dropped:
                s = h.summary(scale=1e3)
                out[name[len(LAT_PREFIX):]] = {
                    "count": s["count"], "mean_ms": s["mean"],
                    "min_ms": s["min"], "max_ms": s["max"],
                    **{f"p{p:g}_ms": s[f"p{p:g}"] for p in PERCENTILES}}
            else:
                out[name[len(LAT_PREFIX):]] = summarize(h.samples)
        return out

    def throughput(self, ops) -> dict[str, float]:
        """Sustained units/s per op over the shared wall window (ops
        overlap on device, so these are *service* rates, not inverse
        latencies)."""
        wall = max(self.wall_s, 1e-9)
        return {op: self._counts[op] / wall for op in ops}
