"""Query micro-batcher: coalesce kNN/range requests into pow2-padded
batches that hit the QueryEngine's jit-cached plans.

Serving traffic arrives as many small requests (a handful of query
points each), but the :class:`repro.core.engine.QueryEngine` caches its
jitted query plans on the *batch* signature ``(op, Q-shape, dtype,
k/caps, impl)`` — the same signature-keying pattern as
``repro.core.index._update_closure`` and ``repro.serve.engine``'s
prefill/decode closures. Dispatching each request alone would retrace
per distinct request size and waste the accelerator on tiny launches.

The :class:`MicroBatcher` instead queues requests per plan signature
``(op, k, dim, dtype, impl)``, concatenates them, and **pads the
coalesced batch to the next power of two** (replicating the final row —
rows are independent under vmap, so padding never perturbs real
answers). Batched answers are sliced back per request, and because every
engine impl is exact and canonically (d2, id)-ordered, kNN and
range-count answers **bit-match the answers the same requests would get
dispatched alone** (asserted in tests/test_serving.py); range-list
answers match in counts and id *sets*, but the padded id width is
sized by the coalesced batch's largest output, so it can exceed the
solo-dispatch width. Pow2 padding means a workload with arbitrary
ragged request sizes visits at most O(log max_batch) distinct Q shapes,
so the engine's plan cache converges after warmup (also asserted, via
``repro.core.engine.trace_count``).

Admission policy (cooperative — there is no background timer thread):
a flush is forced when pending rows reach ``max_batch``, or when the
oldest queued request has waited ``max_delay_s`` *as observed at the
next interaction point* — a ``submit``, an explicit ``poll()``, or a
``Ticket.result()`` (which always flushes whatever is pending, so no
request waits forever). ``max_delay_s=0`` disables coalescing-by-wait:
every submit flushes immediately. Trickle traffic that only polls
``Ticket.done`` should call ``poll()`` in its wait loop.

Requests submitted as host (numpy) rows stay host-side until flush —
one concatenate + one device transfer per coalesced batch — while
device-array requests are concatenated on device; the two never race
because grouping is per plan signature.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core.engine import _pow2


def _as_rows(x):
    """Normalize one request payload to a 2-D row batch, keeping host
    arrays on host (device transfer is deferred to the flush)."""
    if isinstance(x, jax.Array):
        return jnp.atleast_2d(x)
    # contract: allow[host-sync-in-dispatch] this branch only ever sees
    # host payloads (device arrays returned above); np.asarray here is a
    # host-side copy, not a device read
    return np.atleast_2d(np.asarray(x))


def _concat_pad(parts, rows: int):
    """Concatenate request payloads and pad to the next pow2 row count
    by replicating the last row (rows are independent under vmap, so
    pad rows cannot perturb real answers)."""
    xp = jnp if any(isinstance(p, jax.Array) for p in parts) else np
    col = xp.concatenate(parts)
    pad = _pow2(rows) - rows
    if pad:
        col = xp.concatenate([col, xp.repeat(col[-1:], pad, axis=0)])
    return col


class Ticket:
    """Handle for one submitted request; ``result()`` forces a flush of
    the owning batcher if the answer is not in yet."""

    __slots__ = ("_batcher", "_value", "_done")

    def __init__(self, batcher: "MicroBatcher"):
        self._batcher = batcher
        self._done = False
        self._value = None

    @property
    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            self._batcher.flush(reason="result")
        assert self._done, "flush did not resolve this ticket"
        return self._value

    def _resolve(self, value) -> None:
        self._value = value
        self._done = True


class MicroBatcher:
    """Coalesces kNN / range-count / range-list requests per plan
    signature; see the module docstring for the contract.

    ``target`` is what answers the flushed batches: a
    :class:`repro.core.SpatialIndex`, a ``repro.serving.Snapshot``, or
    a zero-arg callable returning either (e.g. ``server.snapshot`` — the
    snapshot is then taken at *flush* time, so one flush answers against
    one consistent version). Reassigning ``target`` drains pending
    requests first: they were submitted against the old target, and
    answering them from a newer version would misattribute results.
    """

    def __init__(self, target=None, *, max_batch: int = 1024,
                 max_delay_s: float = 0.002, clock=time.monotonic):
        self._target = target
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self._clock = clock
        self._groups: dict[tuple, list] = {}
        self._pending_rows = 0
        self._oldest = None
        self.flushes = 0

    @property
    def target(self):
        return self._target

    @target.setter
    def target(self, value):
        # requests already queued were submitted against the old target;
        # answering them from a newer version would silently break the
        # snapshot attribution, so drain first
        if self._pending_rows and value is not self._target:
            self.flush(reason="retarget")
        self._target = value

    # -- submission --------------------------------------------------------

    def submit_knn(self, qpts, k: int, *, impl: str = "auto") -> Ticket:
        """Queue a kNN request (1 or more query points); the ticket
        resolves to the same ``(d2, ids)`` the request would get from
        ``index.knn(qpts, k, impl=impl)``."""
        qpts = _as_rows(qpts)
        key = ("knn", int(k), qpts.shape[1], str(qpts.dtype), impl)
        return self._enqueue(key, (qpts,), qpts.shape[0])

    def submit_range_count(self, lo, hi) -> Ticket:
        """Queue a range-count request (1 or more boxes)."""
        lo, hi = _as_rows(lo), _as_rows(hi)
        key = ("range_count", lo.shape[1], str(lo.dtype))
        return self._enqueue(key, (lo, hi), lo.shape[0])

    def submit_range_list(self, lo, hi) -> Ticket:
        """Queue a range-list request; resolves to ``(ids, counts)``."""
        lo, hi = _as_rows(lo), _as_rows(hi)
        key = ("range_list", lo.shape[1], str(lo.dtype))
        return self._enqueue(key, (lo, hi), lo.shape[0])

    def _enqueue(self, key: tuple, arrays: tuple, rows: int) -> Ticket:
        t = Ticket(self)
        now = self._clock()
        self._groups.setdefault(key, []).append((t, arrays, rows, now))
        self._pending_rows += rows
        obs.gauge("batcher.queue_depth", self._pending_rows)
        if self._oldest is None:
            self._oldest = now
        if self._pending_rows >= self.max_batch:
            self.flush(reason="size")
        elif now - self._oldest >= self.max_delay_s:
            self.flush(reason="deadline")
        return t

    @property
    def pending(self) -> int:
        """Queued request rows not yet flushed."""
        return self._pending_rows

    def poll(self) -> int:
        """Flush if the oldest queued request has exceeded the delay
        deadline (for trickle-traffic wait loops that watch
        ``Ticket.done`` instead of calling ``result()``); returns the
        number of engine calls issued."""
        if (self._oldest is not None
                and self._clock() - self._oldest >= self.max_delay_s):
            return self.flush(reason="deadline")
        return 0

    # -- execution ---------------------------------------------------------

    def _resolve_target(self):
        t = self.target() if callable(self.target) else self.target
        if t is None:
            raise ValueError("MicroBatcher.target is not set")
        return t

    def flush(self, *, reason: str = "explicit") -> int:
        """Execute every pending group as one pow2-padded batch each;
        returns the number of batched engine calls issued. ``reason``
        (size | deadline | result | retarget | explicit) is recorded on
        the ``batcher.flush.<reason>`` obs counter."""
        groups, self._groups = self._groups, {}
        self._pending_rows, self._oldest = 0, None
        if not groups:
            return 0
        obs.count(f"batcher.flush.{reason}")
        target = self._resolve_target()
        now = self._clock()
        calls = 0
        for key, reqs in groups.items():
            self._run_group(target, key, reqs, now)
            calls += 1
        self.flushes += calls
        return calls

    def _run_group(self, target, key: tuple, reqs: list, now) -> None:
        op = key[0]
        q = sum(r[2] for r in reqs)
        obs.count("batcher.requests", len(reqs))
        obs.observe("batcher.coalesce_rows", q)
        obs.observe("batcher.pad_rows", _pow2(q) - q)
        for _, _, _, ts in reqs:
            obs.observe("batcher.wait_s", now - ts)
        with obs.span("batcher.flush", op=op, rows=q, reqs=len(reqs)):
            cols = [_concat_pad([r[1][i] for r in reqs], q)
                    for i in range(len(reqs[0][1]))]
            if op == "knn":
                # local indexes answer (d2, ids); distributed snapshots
                # answer (d2, points, valid) — slice whatever came back
                outs = tuple(target.knn(cols[0], key[1], impl=key[4]))
            elif op == "range_count":
                outs = (target.range_count(cols[0], cols[1]),)
            else:
                ids, cnt = target.range_list(cols[0], cols[1])
                outs = (ids, cnt)
        start = 0
        for ticket, _, rows, _ts in reqs:
            sl = tuple(o[start: start + rows] for o in outs)
            ticket._resolve(sl if len(sl) > 1 else sl[0])
            start += rows
