"""``SpatialServer``: a versioned spatial index with snapshot-isolated
queries and pipelined (async-dispatched) updates.

The trees behind :class:`repro.core.SpatialIndex` are functional —
every update returns a new handle and never mutates the old one — so a
*snapshot* is free: it is just a reference to version ``v``'s handle.
The server exploits that plus JAX async dispatch to overlap updates and
queries with **no barrier between them**:

* ``insert``/``delete`` dispatch version ``v+1``'s jit-cached update
  closure and return immediately (dynamic backends only enqueue device
  work; rebuild-style kd/zd stay synchronous — their size verification
  needs a host read). The facade's usual host-side ``overflowed`` read
  — a full device sync — is **deferred**: the flag is sticky across
  updates (spac/porth carry it forward), so one read at the next sync
  point covers every update since the last known-good version.
* ``snapshot()`` hands out an immutable :class:`Snapshot` of any
  retained version; queries against it are answered from exactly that
  version's tree even while later updates are in flight on device
  (asserted bit-for-bit in tests/test_serving.py).
* A **bounded version window** (``window=``) is the backpressure knob:
  publishing version ``v+1`` evicts version ``v-window`` and blocks on
  it, so at most ``window`` updates are ever in flight and device queue
  depth (and retained-tree memory) stays bounded.
* ``commit()`` is the explicit barrier: it blocks on the head version,
  performs the deferred overflow check, and reclaims old versions. If
  any deferred insert overflowed, the server **replays the op log from
  the last good version** through the facade's synchronous
  grow->retry->compact recovery, so a committed head always holds the
  exact multiset of every op applied in order — callers never lose
  points. (Size the server with ``capacity_points=`` for the lifetime
  maximum and replay never triggers; ``stats["recoveries"]`` counts it.)

Snapshot isolation requires old versions' buffers to stay live, so the
server refuses a ``donate=True`` index — the bounded window replaces
donation as the memory-control mechanism.

The same lineage fronts a mesh-sharded head
(:class:`repro.core.index.DistributedIndex`, ``build(..., mesh=)``):
updates dispatch through the cached shard_map exchange and queries
through the engine's distributed merge, both version-functional, so
snapshots/window/commit work unchanged. Distribution adds a second
deferred failure signal next to sticky ``overflowed`` (now a per-shard
vector, reduced with :func:`_overflowed`): the routing slab's
``dropped`` counter. Both are checked at the same sync points and both
trigger the same commit-time replay — see tests/test_serving_distributed.py
and ROADMAP "Distributed serving (PR 10)".
"""

from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core import make_index
from ..core.index import DistributedIndex, SpatialIndex


def _overflowed(tree) -> bool:
    """Deferred sticky-overflow read, shape-agnostic: scalar flag on a
    local tree, per-shard (n_shards,) vector on a distributed head (any
    shard overflowing dirties the version)."""
    flag = getattr(tree, "overflowed", None)
    return flag is not None and bool(jnp.any(flag))


class Snapshot:
    """Immutable view of one server version; queries delegate to the
    underlying :class:`SpatialIndex` (same engine, same cached plans)
    and are isolated from every later update."""

    __slots__ = ("version", "index")

    def __init__(self, version: int, index: SpatialIndex):
        self.version = version
        self.index = index

    def knn(self, qpts, k: int, *, impl: str = "auto"):
        return self.index.knn(qpts, k, impl=impl)

    def knn_points(self, qpts, k: int, *, impl: str = "auto"):
        return self.index.knn_points(qpts, k, impl=impl)

    def range_count(self, lo, hi):
        return self.index.range_count(lo, hi)

    def range_list(self, lo, hi):
        return self.index.range_list(lo, hi)

    @property
    def size(self):
        return self.index.size

    def __len__(self) -> int:
        return len(self.index)

    def __repr__(self):
        return f"Snapshot(version={self.version}, kind={self.index.kind!r})"


class SpatialServer:
    """Owns a lineage of :class:`SpatialIndex` versions; see the module
    docstring for the pipelining/backpressure/commit contract."""

    def __init__(self, index: SpatialIndex, *, window: int = 4):
        if getattr(index, "_donate", False):
            raise ValueError(
                "SpatialServer requires a non-donating index: snapshots "
                "keep old versions' buffers live, which donate=True would "
                "hand to the next update; the bounded version window "
                "(window=) bounds memory instead")
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = int(window)
        self._versions: OrderedDict[int, SpatialIndex] = OrderedDict()
        self._head = 0
        self._versions[0] = index
        # memory accounting: bytes per retained version from leaf
        # ``nbytes`` metadata (shape/dtype arithmetic — no device read,
        # see repro.obs.memory), plus window aggregates. peak_window
        # is the high-water mark of retained bytes; evicted_* count
        # window-pressure evictions only (commit-time reclamation is a
        # barrier, not backpressure).
        nb = obs.tree_bytes(index.tree)
        self._version_bytes: dict[int, int] = {0: nb}
        self.mem = {"live_bytes": nb, "window_bytes": nb,
                    "peak_window_bytes": nb, "evicted_bytes": 0,
                    "evictions": 0}
        # recovery state: the last version whose (sticky) overflow flag
        # was read clean, plus every op dispatched since
        self._base = 0
        self._base_index = index
        # distributed heads add a second sticky failure signal: the
        # routing-slab `dropped` counter. Construction is a sync point,
        # so reading the baseline here is free; dispatch paths only ever
        # compare against it at eviction/commit barriers.
        self._distributed = isinstance(index, DistributedIndex)
        self._base_dropped = (int(index.dropped) if self._distributed
                              else 0)
        self._log: list[tuple[str, object, object]] = []
        self.stats = {"inserts": 0, "deletes": 0, "commits": 0,
                      "recoveries": 0, "update_points": 0}
        # device-side row counts not yet folded into update_points;
        # commit() (already a barrier) reads them off-device
        self._deferred_points: list = []

    @classmethod
    def build(cls, kind: str, points, *, window: int = 4, **make_kw):
        """Build a fresh index via :func:`repro.core.make_index` and wrap
        it; pass ``capacity_points=`` for the lifetime maximum so the
        deferred overflow check never trips."""
        if make_kw.get("donate"):
            raise ValueError("SpatialServer does not support donate=True")
        return cls(make_index(kind, points, **make_kw), window=window)

    # -- introspection -----------------------------------------------------

    @property
    def head_version(self) -> int:
        return self._head

    @property
    def head_index(self) -> SpatialIndex:
        return self._versions[self._head]

    @property
    def versions(self) -> tuple[int, ...]:
        """Retained version ids, oldest first."""
        return tuple(self._versions)

    @property
    def in_flight(self) -> int:
        """Updates dispatched since the last commit (upper bound on
        device work not yet known complete)."""
        return self._head - self._base

    def snapshot(self, version: int | None = None) -> Snapshot:
        """A consistent view of ``version`` (default: head). Raises
        ``KeyError`` for versions outside the retained window."""
        v = self._head if version is None else int(version)
        try:
            return Snapshot(v, self._versions[v])
        except KeyError:
            raise KeyError(
                f"version {v} not retained (window holds "
                f"{list(self._versions)})") from None

    # -- updates (async dispatch) ------------------------------------------

    def _live_rows(self, pts, mask) -> int:
        """Rows contributed to ``stats["update_points"]`` — without a
        device sync on the dispatch path. A device mask is summed *on
        device* and folded into the stat at the next ``commit()`` (a
        barrier anyway), so ``update_points`` is exact at sync points
        and a lower bound between them."""
        if mask is None:
            return int(pts.shape[0])
        if isinstance(mask, jax.Array):
            self._deferred_points.append(jnp.sum(mask, dtype=jnp.int32))
            return 0
        # host-side mask: popcount without touching the device
        return int(np.count_nonzero(mask))

    def insert(self, pts, mask=None) -> int:
        """Dispatch a batch insert as version ``head+1``; returns the new
        version id without waiting for the device (dynamic backends)."""
        with obs.span("serving.insert") as sp:
            pts = jnp.asarray(pts)
            sp.set(rows=pts.shape[0], version=self._head + 1)
            new = self.head_index.insert_unchecked(pts, mask)
            self.stats["inserts"] += 1
            self.stats["update_points"] += self._live_rows(pts, mask)
            return self._publish(new, ("insert", pts, mask))

    def delete(self, pts, mask=None) -> int:
        """Dispatch a batch delete as version ``head+1`` (deletes never
        overflow rows; distributed heads defer their routing-slab
        ``dropped`` check, so dispatch stays async there too)."""
        with obs.span("serving.delete") as sp:
            pts = jnp.asarray(pts)
            sp.set(rows=pts.shape[0], version=self._head + 1)
            new = self.head_index.delete_unchecked(pts, mask)
            self.stats["deletes"] += 1
            self.stats["update_points"] += self._live_rows(pts, mask)
            return self._publish(new, ("delete", pts, mask))

    def _publish(self, index: SpatialIndex, op: tuple) -> int:
        self._head += 1
        self._versions[self._head] = index
        self._log.append(op)
        nb = obs.tree_bytes(index.tree)       # metadata only, no sync
        self._version_bytes[self._head] = nb
        mem = self.mem
        mem["live_bytes"] = nb
        mem["window_bytes"] += nb
        while len(self._versions) > self.window:
            v, old = self._versions.popitem(last=False)
            freed = self._version_bytes.pop(v, 0)
            mem["window_bytes"] -= freed
            mem["evicted_bytes"] += freed
            mem["evictions"] += 1
            obs.count("server.mem.evicted_bytes", freed)
            obs.count("server.mem.evictions")
            # backpressure: everything up to the evicted version must be
            # done before more updates pile on; its (now free) overflow
            # read doubles as an early deferred check
            with obs.span("serving.evict_block", version=v):
                # contract: allow[host-sync-in-dispatch] window eviction
                # is the designed backpressure point; waiting on the
                # *evicted* version bounds device-queue depth without
                # stalling head
                jax.block_until_ready(old.tree)
            # past the barrier both sticky reads are free; a distributed
            # version is dirty if any shard overflowed OR the routing
            # slab dropped entries since the last clean baseline
            dirty = _overflowed(old.tree) or (
                self._distributed
                and int(old.dropped) != self._base_dropped)
            if dirty:
                self._recover()
            elif v > self._base:
                # fast-forward the recovery base: ops up to v are clean
                del self._log[: v - self._base]
                self._base, self._base_index = v, old
        if mem["window_bytes"] > mem["peak_window_bytes"]:
            mem["peak_window_bytes"] = mem["window_bytes"]
        obs.gauge("server.mem.live_bytes", mem["live_bytes"])
        obs.gauge("server.mem.window_bytes", mem["window_bytes"])
        return self._head

    # -- sync points -------------------------------------------------------

    def commit(self) -> int:
        """Barrier: wait for the head version, run the deferred overflow
        check (replaying from the last good version on overflow), and
        reclaim every older version. Returns the committed version id."""
        with obs.span("serving.commit") as sp:
            sp.set(version=self._head, in_flight=self._head - self._base)
            head = self._versions[self._head]
            jax.block_until_ready(head.tree)
            if _overflowed(head.tree) or (
                    self._distributed
                    and int(head.dropped) != self._base_dropped):
                head = self._recover()
            if self._deferred_points:
                # past the barrier these reads are free; see _live_rows
                self.stats["update_points"] += sum(
                    int(x) for x in self._deferred_points)
                self._deferred_points = []
            self._base, self._base_index = self._head, head
            if self._distributed:
                self._base_dropped = int(head.dropped)
            self._log = []
            self._versions = OrderedDict({self._head: head})
            self._rebase_memory(head)
            self.stats["commits"] += 1
            # commit is THE barrier: deferred obs device reads (span
            # attachments, deferred counters) resolve here for free
            obs.resolve()
            return self._head

    def _recover(self) -> SpatialIndex:
        """Replay the op log from the last good version through the
        facade's synchronous recovery path (grow -> retry -> compact),
        making the head exact again after a deferred overflow."""
        with obs.span("serving.replay", ops=len(self._log),
                      base=self._base, head=self._head):
            idx = self._base_index
            for op, pts, mask in self._log:
                idx = (idx.insert(pts, mask) if op == "insert"
                       else idx.delete(pts, mask))
            jax.block_until_ready(idx.tree)
        self._versions = OrderedDict({self._head: idx})
        self._base, self._base_index = self._head, idx
        if self._distributed:
            # the replayed head is the new clean baseline for the
            # routing-slab counter (checked ops guarantee no new drops,
            # but a mid-replay re-shard resets the cumulative count)
            self._base_dropped = int(idx.dropped)
        self._log = []
        self._rebase_memory(idx)
        self.stats["recoveries"] += 1
        return idx

    # -- memory accounting -------------------------------------------------

    def _rebase_memory(self, index: SpatialIndex) -> None:
        """The window just collapsed to head only (commit/recover):
        recompute the byte aggregates from the surviving version."""
        nb = obs.tree_bytes(index.tree)
        self._version_bytes = {self._head: nb}
        mem = self.mem
        mem["live_bytes"] = nb
        mem["window_bytes"] = nb
        if nb > mem["peak_window_bytes"]:
            mem["peak_window_bytes"] = nb
        obs.gauge("server.mem.live_bytes", nb)
        obs.gauge("server.mem.window_bytes", nb)

    def memory_report(self) -> dict:
        """Copy of the byte aggregates plus per-retained-version bytes.
        All values come from array metadata — calling this never syncs
        the device, so it is safe between commits."""
        return {**self.mem,
                "version_bytes": dict(self._version_bytes),
                "retained": len(self._versions)}

    def __repr__(self):
        return (f"SpatialServer(kind={self.head_index.kind!r}, "
                f"head={self._head}, window={self.window}, "
                f"retained={len(self._versions)})")
