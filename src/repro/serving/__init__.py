"""``repro.serving`` — the versioned spatial serving runtime.

The paper's workload is *highly dynamic*: batch updates must land at
low latency while queries keep being answered. This package is that
setting as a runtime (ROADMAP "Serving runtime (PR 3)"):

* :class:`SpatialServer` (``server``) — a versioned
  :class:`repro.core.SpatialIndex`: snapshots are free (functional
  trees), updates dispatch asynchronously so queries against version
  ``v`` overlap version ``v+1``'s update on device, a bounded version
  window gives backpressure, and ``commit()`` is the explicit barrier
  with a deferred (replay-on-overflow) capacity check.
* :class:`MicroBatcher` (``batcher``) — coalesces single kNN/range
  requests into pow2-padded batches that hit the
  :class:`repro.core.engine.QueryEngine`'s jit-cached plans (the
  ``_update_closure`` signature-keying pattern); answers bit-match
  per-request dispatch.
* :mod:`driver` / :class:`LatencyRecorder` (``metrics``) — a workload
  driver replaying deterministic mixed update/query traces
  (``repro.data.points.make_trace``) and reporting per-op p50/p95/p99
  plus sustained q/s and update-points/s.

``python -m repro.serving.driver --smoke`` runs the whole stack on a
tiny trace (the CI fast-tier smoke); ``launch/serve.py --service
index`` and ``examples/dynamic_index_serving.py`` are thin frontends
over this package.
"""

from .batcher import MicroBatcher, Ticket  # noqa: F401
from .metrics import LatencyRecorder, summarize  # noqa: F401
from .server import Snapshot, SpatialServer  # noqa: F401

__all__ = ["LatencyRecorder", "MicroBatcher", "Snapshot",
           "SpatialServer", "Ticket", "summarize"]
