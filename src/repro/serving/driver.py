"""Workload driver: replay deterministic mixed update/query traces
through the serving runtime and report latency percentiles.

Per (backend, scenario) the driver builds a :class:`SpatialServer`
sized for the trace's peak live points, then replays the trace's steps
in the pipelined serving pattern:

1. take a snapshot of the current head version,
2. dispatch the step's delete + insert (async — versions ``v+1``,
   ``v+2`` go in flight; only the dispatch time is on the critical
   path),
3. answer the step's kNN and range requests **against the pre-step
   snapshot** through the :class:`MicroBatcher` (requests arrive as
   single-query submissions and coalesce into one pow2-padded batch per
   op — their device work overlaps the in-flight updates),
4. ``commit()`` — the only barrier; its wall time is the *exposed*
   update stall, i.e. whatever the queries did not hide.

Recorded ops: ``insert`` / ``delete`` (dispatch latency), ``knn`` /
``range`` (request submit -> result, including device wait) plus their
``_dispatch`` / ``_wait`` segments (host submit+flush time vs device
wait — the split that attributes a round-trip), and ``commit`` (exposed
update stall). Warmup steps run the identical shapes first and are
dropped, so jit compiles and the query engine's pow2 bucket-escalation
retraces never pollute a percentile (the first-timed-batch skew the old
``launch/serve.py`` loop had).

Observability (PR 7): percentiles come from ``repro.obs`` histograms —
install a recorder (or pass ``--obs-trace``) and the same sink collects
the library's own counters/spans (plan-cache traffic, batcher queue
depth/pad waste, commit stalls) and exports a Perfetto-viewable chrome
trace; ``--attributed`` replays one scenario obs-off vs obs-on
side-by-side and writes the attributed kNN round-trip baseline
(``results/serve_trace.json``).

Scenarios are ``repro.data.points.SCENARIOS``: churn over each point
distribution (uniform / sweepline / varden) plus the dynamic shapes
``moving-objects`` and ``sliding-window``.

Run:
  PYTHONPATH=src python -m repro.serving.driver --kinds porth,spac-h
  PYTHONPATH=src python -m repro.serving.driver --smoke
  PYTHONPATH=src python -m repro.serving.driver --json  # results/...
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from .. import obs
from ..data import points as gen
from .batcher import MicroBatcher
from .metrics import LatencyRecorder
from .server import SpatialServer

DEFAULT_KINDS = ("porth", "spac-h")
DEFAULT_JSON = "results/serve_latency.json"
DEFAULT_OBS_TRACE = "results/obs_trace.json"
DEFAULT_SERVE_TRACE = "results/serve_trace.json"


@dataclasses.dataclass(frozen=True)
class DriverCfg:
    n: int = 20_000           # bootstrap / live-set size
    batch: int = 512          # update batch per step
    steps: int = 6            # measured steps
    warmup: int = 2           # untimed steps (same shapes) dropped
    queries: int = 64         # kNN + range requests per step
    k: int = 10
    box_frac: int = 64        # range boxes span DEFAULT_HI / box_frac
    window: int = 4           # server version window
    # admission knob: high default so flushes are size-triggered (one
    # pow2 shape per op) and a timing-dependent split never compiles a
    # fresh shape inside the measured window; lower it to trade
    # throughput for per-request latency
    max_delay_ms: float = 50.0
    seed: int = 0
    dim: int = 2
    phi: int = 32
    mesh: int = 0             # simulated shard count (0 = single-device)


def _query_stream(cfg: DriverCfg, scenario: str, step: int):
    """Deterministic per-step query load: kNN points from the scenario's
    distribution (uniform for the dynamic shapes) + range boxes."""
    dist = scenario if scenario in gen.GENERATORS else "uniform"
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 7), step)
    k1, k2 = jax.random.split(key)
    qpts = gen.GENERATORS[dist](k1, cfg.queries, cfg.dim)
    lo, hi = gen.query_boxes(k2, cfg.queries, cfg.dim,
                             gen.DEFAULT_HI // cfg.box_frac)
    # requests arrive as host-side rows (as they would off the wire);
    # numpy slicing keeps per-submit overhead off the device
    return np.asarray(qpts), np.asarray(lo), np.asarray(hi)


def run_one(kind: str, scenario: str, cfg: DriverCfg,
            verbose: bool = False, mesh=None) -> dict:
    """Replay one (backend, scenario) trace; returns latency summary +
    sustained throughput for the measured window. With ``mesh`` the
    server's head index is mesh-sharded (``DistributedIndex``) and the
    summary gains a per-shard ``distributed`` section."""
    total = cfg.warmup + cfg.steps
    trace = gen.make_trace(scenario, seed=cfg.seed, n=cfg.n,
                           batch=cfg.batch, steps=total, dim=cfg.dim)
    t0 = time.perf_counter()
    srv = SpatialServer.build(kind, trace.bootstrap, phi=cfg.phi,
                              capacity_points=trace.max_live,
                              window=cfg.window, mesh=mesh)
    jax.block_until_ready(srv.head_index.tree)
    build_s = time.perf_counter() - t0
    batcher = MicroBatcher(max_batch=cfg.queries,
                           max_delay_s=cfg.max_delay_ms / 1e3)
    # share the installed obs recorder (if any) so latency histograms,
    # the library's own counters/spans, and trace export use one sink
    rec = LatencyRecorder(recorder=obs.recorder())
    measured_updates = 0
    for s, step in enumerate(trace.steps):
        if s == cfg.warmup:
            rec.reset()   # drop warmup: compiles + bucket escalations
        snap = srv.snapshot()                       # pre-step version
        batcher.target = snap
        if step.delete is not None:
            with rec.timer("delete", step.delete.shape[0]):
                srv.delete(step.delete)             # async dispatch
        if step.insert is not None:
            with rec.timer("insert", step.insert.shape[0]):
                srv.insert(step.insert)             # async dispatch
        # micro-batched queries against the snapshot: single-query
        # requests coalesce into one pow2-padded engine call per op,
        # overlapping the in-flight updates on device
        qpts, lo, hi = _query_stream(cfg, scenario, s)
        t1 = time.perf_counter()
        knn_tickets = [batcher.submit_knn(qpts[i], cfg.k)
                       for i in range(cfg.queries)]
        answers = [t.result() for t in knn_tickets]
        t2 = time.perf_counter()       # dispatched: host work done
        jax.block_until_ready(answers)
        t3 = time.perf_counter()       # device drained
        rec.record("knn", t3 - t1, cfg.queries, start=t1)
        rec.record("knn_dispatch", t2 - t1, cfg.queries)
        rec.record("knn_wait", t3 - t2, cfg.queries)
        t1 = time.perf_counter()
        rng_tickets = [batcher.submit_range_count(lo[i], hi[i])
                       for i in range(cfg.queries)]
        answers = [t.result() for t in rng_tickets]
        t2 = time.perf_counter()
        jax.block_until_ready(answers)
        t3 = time.perf_counter()
        rec.record("range", t3 - t1, cfg.queries, start=t1)
        rec.record("range_dispatch", t2 - t1, cfg.queries)
        rec.record("range_wait", t3 - t2, cfg.queries)
        with rec.timer("commit"):                   # exposed stall
            srv.commit()
        if s >= cfg.warmup:
            measured_updates += \
                (0 if step.delete is None else step.delete.shape[0]) + \
                (0 if step.insert is None else step.insert.shape[0])
    wall = rec.wall_s
    mem = srv.memory_report()
    out = {
        "latency_ms": rec.latency_summary(),
        "throughput": {
            "query_per_s": rec.count("knn") + rec.count("range"),
            "update_pts_per_s": measured_updates,
            "wall_s": wall,
        },
        # per-scenario memory: steady = head-version bytes at the end,
        # peak = retained-window high-water mark; all from nbytes
        # metadata (repro.obs.memory), so recording it costs no sync
        "memory": {
            "steady_bytes": mem["live_bytes"],
            "peak_window_bytes": mem["peak_window_bytes"],
            "window_bytes": mem["window_bytes"],
            "evicted_bytes": mem["evicted_bytes"],
            "evictions": mem["evictions"],
        },
        "build_s": build_s,
        "final_size": len(srv.head_index),
        "recoveries": srv.stats["recoveries"],
    }
    if mesh is not None:
        # per-shard balance report: live points per shard from the
        # key-range routing, plus the cumulative routing-drop counter
        # (0 after checked updates / commit — drops trigger replay)
        sizes = np.asarray(srv.head_index.shard_sizes())
        for i, s in enumerate(sizes.tolist()):
            obs.gauge(f"server.shard{i}.live_points", int(s))
        out["distributed"] = {
            "n_shards": int(sizes.shape[0]),
            "shard_points": [int(s) for s in sizes.tolist()],
            "shard_min_points": int(sizes.min()),
            "shard_max_points": int(sizes.max()),
            "dropped": int(srv.head_index.dropped),
        }
    for key in ("query_per_s", "update_pts_per_s"):
        out["throughput"][key] = out["throughput"][key] / max(wall, 1e-9)
    if verbose:
        lat = out["latency_ms"]
        cells = " ".join(
            f"{op} p50={lat[op]['p50_ms']:7.2f} p99={lat[op]['p99_ms']:7.2f}"
            for op in ("insert", "delete", "knn", "range", "commit")
            if op in lat and lat[op]["count"])
        print(f"  [{kind}/{scenario}] {cells} | "
              f"{out['throughput']['query_per_s']:,.0f} q/s, "
              f"{out['throughput']['update_pts_per_s']:,.0f} upd-pts/s | "
              f"mem {obs.fmt_bytes(mem['live_bytes'])} steady / "
              f"{obs.fmt_bytes(mem['peak_window_bytes'])} peak",
              flush=True)
        if mesh is not None:
            d = out["distributed"]
            print(f"    shards={d['n_shards']} "
                  f"points/shard min={d['shard_min_points']} "
                  f"max={d['shard_max_points']} "
                  f"dropped={d['dropped']}", flush=True)
    return out


def run(kinds=DEFAULT_KINDS, scenarios=gen.SCENARIOS,
        cfg: DriverCfg = DriverCfg(), verbose: bool = True,
        mesh=None) -> dict:
    """Sweep kinds x scenarios; returns the full json-able payload."""
    payload = {"config": dataclasses.asdict(cfg), "kinds": list(kinds),
               "scenarios": list(scenarios), "results": {}}
    for kind in kinds:
        if verbose:
            print(f"{kind}:", flush=True)
        payload["results"][kind] = {
            scenario: run_one(kind, scenario, cfg, verbose=verbose,
                              mesh=mesh)
            for scenario in scenarios}
    return payload


def _p50(stats: dict | None) -> float:
    return float((stats or {}).get("p50_ms", 0.0))


DEFAULT_ROOFLINE = "results/roofline.json"


def _cost_model_section(kind: str, counters: dict) -> dict:
    """Expected-vs-observed device time from captured plan costs.

    The obs-on run records each compiled plan's HLO byte traffic
    (``plan.cost.*``, see repro.obs.costs). Dividing the dominant kNN
    plan's bytes by the backend's kNN byte rate from the committed
    roofline baseline gives the time the cost model *expects* the
    whole kernel execution to take. Units must match: the rate comes
    from the roofline cell's own captured plan (``plan_hlo_bytes`` /
    ``time_s`` — HLO traffic over measured wall), falling back to the
    analytic ``achieved_gbytes_s`` (useful-work bytes) only for old
    baselines, where the expected time overshoots by the structure's
    ``hlo_vs_model_bytes`` factor. Compare against dispatch + device
    wait — async dispatch hides most device time inside the blocking
    ``.result()`` — to see what the model misses (queueing, launch
    gaps, cache effects). Returns nulls when nothing was captured or
    the baseline is absent."""
    costs = obs.costs.plan_costs(counters)
    out = {"plan_costs": costs, "knn_plan_sig": None,
           "knn_plan_bytes": None, "knn_expected_device_ms": None,
           "rate_source": None}
    knn = {s: c for s, c in costs.items() if s.startswith("knn.")}
    if not knn:
        return out
    sig = max(knn, key=lambda s: knn[s].get("bytes", 0))
    out["knn_plan_sig"] = sig
    out["knn_plan_bytes"] = knn[sig].get("bytes", 0)
    try:
        with open(DEFAULT_ROOFLINE) as f:
            cell = json.load(f)["results"][kind]["knn"]
    except (OSError, KeyError, TypeError, ValueError):
        return out
    if cell.get("plan_hlo_bytes") and cell.get("time_s"):
        rate = cell["plan_hlo_bytes"] / cell["time_s"]
        out["rate_source"] = f"{DEFAULT_ROOFLINE}:plan_hlo_bytes"
    else:
        rate = float(cell.get("achieved_gbytes_s", 0)) * 1e9
        out["rate_source"] = f"{DEFAULT_ROOFLINE}:model_bytes"
    if rate > 0:
        out["knn_expected_device_ms"] = \
            out["knn_plan_bytes"] / rate * 1e3
    else:
        out["rate_source"] = None
    return out


def run_attributed(kinds=DEFAULT_KINDS, scenario: str = "uniform",
                   cfg: DriverCfg = DriverCfg(),
                   verbose: bool = True) -> dict:
    """Replay one scenario per backend twice — obs disabled, then obs
    enabled — and attribute the kNN round-trip from the enabled run's
    obs data: batcher queue wait, host dispatch (plan-cache lookup +
    launch), pow2 buffer escalation, device wait. The side-by-side p50s
    are the recorded evidence that enabling obs does not regress the
    round-trip (acceptance: < 5%); the attributed segments are the
    serve-latency baseline (``results/serve_trace.json``)."""
    payload = {"config": dataclasses.asdict(cfg), "scenario": scenario,
               "kinds": list(kinds), "results": {}}
    for kind in kinds:
        assert not obs.enabled(), "attributed baseline needs obs off"
        off = run_one(kind, scenario, cfg)
        # capture_costs: the obs-on run also AOT-captures each plan's
        # flops/bytes (during warmup, where the plan misses happen, so
        # the measured percentiles never see the extra compile)
        with obs.recording(obs.Recorder(capture_costs=True)) as rec_obs:
            on = run_one(kind, scenario, cfg)
            report = rec_obs.report()
        hists, counters = report["hists"], report["counters"]
        lat_off, lat_on = off["latency_ms"], on["latency_ms"]
        p50_off, p50_on = _p50(lat_off.get("knn")), _p50(lat_on.get("knn"))
        wait = hists.get("batcher.wait_s", {})
        esc = hists.get("engine.escalation_rounds", {})
        requests = counters.get("engine.plan_request", 0)
        misses = counters.get("engine.plan_miss", 0)
        entry = {
            "obs_off": {"latency_ms": lat_off,
                        "throughput": off["throughput"]},
            "obs_on": {"latency_ms": lat_on,
                       "throughput": on["throughput"]},
            "knn_p50_ms": {"obs_off": p50_off, "obs_on": p50_on,
                           "obs_overhead_pct": 0.0 if not p50_off else
                           100.0 * (p50_on - p50_off) / p50_off},
            # round-trip attribution (ms at p50, from the obs-on run):
            # queue wait happens before dispatch, so segments sum to
            # roughly wait + round_trip for a coalesced request
            "knn_attribution_ms": {
                "batcher_wait_p50": wait.get("p50", 0.0) * 1e3,
                "dispatch_p50": _p50(lat_on.get("knn_dispatch")),
                "device_wait_p50": _p50(lat_on.get("knn_wait")),
                "round_trip_p50": p50_on,
            },
            "plan_cache": {
                "requests": requests, "misses": misses,
                "hit_rate": 0.0 if not requests else
                (requests - misses) / requests,
                "traces": counters.get("engine.trace", 0),
            },
            "escalation": {
                "calls": esc.get("count", 0),
                "rounds_p50": esc.get("p50", 0.0),
                "rounds_max": esc.get("max", 0.0),
                "extra_rounds": counters.get("engine.escalation", 0),
            },
            "batcher": {
                "coalesce_rows_p50":
                    hists.get("batcher.coalesce_rows", {}).get("p50", 0.0),
                "pad_rows_p50":
                    hists.get("batcher.pad_rows", {}).get("p50", 0.0),
                "flushes": {k.split(".", 2)[2]: v
                            for k, v in counters.items()
                            if k.startswith("batcher.flush.")},
            },
            # expected (plan-cost model x roofline rate) vs observed
            # device wait; see _cost_model_section
            "cost_model": {
                **_cost_model_section(kind, counters),
                "knn_device_wait_observed_ms":
                    _p50(lat_on.get("knn_wait")),
            },
            "memory": {"obs_off": off.get("memory"),
                       "obs_on": on.get("memory")},
        }
        payload["results"][kind] = entry
        if verbose:
            a = entry["knn_attribution_ms"]
            print(f"[{kind}/{scenario}] knn p50 obs_off={p50_off:.2f}ms "
                  f"obs_on={p50_on:.2f}ms "
                  f"({entry['knn_p50_ms']['obs_overhead_pct']:+.1f}%) | "
                  f"wait={a['batcher_wait_p50']:.2f} "
                  f"dispatch={a['dispatch_p50']:.2f} "
                  f"device={a['device_wait_p50']:.2f}", flush=True)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kinds", default=",".join(DEFAULT_KINDS),
                    help="comma-separated registered backends")
    ap.add_argument("--scenarios", default=",".join(gen.SCENARIOS),
                    help=f"comma-separated from {gen.SCENARIOS}")
    ap.add_argument("--n", type=int, default=DriverCfg.n)
    ap.add_argument("--batch", type=int, default=DriverCfg.batch)
    ap.add_argument("--steps", type=int, default=DriverCfg.steps)
    ap.add_argument("--warmup", type=int, default=DriverCfg.warmup)
    ap.add_argument("--queries", type=int, default=DriverCfg.queries)
    ap.add_argument("--k", type=int, default=DriverCfg.k)
    ap.add_argument("--window", type=int, default=DriverCfg.window)
    ap.add_argument("--max-delay-ms", type=float,
                    default=DriverCfg.max_delay_ms)
    ap.add_argument("--seed", type=int, default=DriverCfg.seed)
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="serve from a DistributedIndex sharded over a "
                    "simulated N-device CPU mesh (stages "
                    "--xla_force_host_platform_device_count before jax "
                    "initializes; adds per-shard metrics)")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON, default=None,
                    metavar="PATH", help="write the latency/throughput "
                    f"payload (default {DEFAULT_JSON})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end trace for CI: one backend, "
                    "every scenario, seconds not minutes")
    ap.add_argument("--obs-trace", nargs="?", const=DEFAULT_OBS_TRACE,
                    default=None, metavar="PATH",
                    help="record the run through repro.obs and export a "
                    "chrome trace (view: python -m repro.obs.view PATH; "
                    f"default {DEFAULT_OBS_TRACE})")
    ap.add_argument("--attributed", nargs="?", const=DEFAULT_SERVE_TRACE,
                    default=None, metavar="PATH",
                    help="obs-off vs obs-on side-by-side on the first "
                    "--scenarios entry, with the kNN round-trip broken "
                    "into batcher-wait/dispatch/device segments "
                    f"(default {DEFAULT_SERVE_TRACE})")
    args = ap.parse_args(argv)
    mesh = None
    if args.mesh:
        # must precede anything that initializes the jax backend (the
        # module-level jax import above is fine — topology locks at the
        # first devices()/array op, not at import)
        from ..configs import platform
        mesh = platform.simulate_mesh(args.mesh)
    rec_obs = obs.install(obs.Recorder()) if args.obs_trace else None

    def _export_obs():
        if rec_obs is None:
            return
        os.makedirs(os.path.dirname(args.obs_trace) or ".", exist_ok=True)
        obs.write_chrome_trace(rec_obs, args.obs_trace)
        obs.uninstall()
        print(f"wrote obs chrome trace -> {args.obs_trace} "
              f"(view: python -m repro.obs.view {args.obs_trace})")

    if args.smoke:
        cfg = DriverCfg(n=1500, batch=128, steps=2, warmup=1, queries=16,
                        k=5, seed=args.seed, mesh=args.mesh)
        payload = run(kinds=("spac-h",), scenarios=gen.SCENARIOS, cfg=cfg,
                      mesh=mesh)
        ops = {op for r in payload["results"]["spac-h"].values()
               for op, s in r["latency_ms"].items() if s["count"]}
        assert {"insert", "delete", "knn", "range", "commit"} <= ops, ops
        if mesh is not None:
            for r in payload["results"]["spac-h"].values():
                d = r["distributed"]
                assert d["n_shards"] == args.mesh, d
                assert sum(d["shard_points"]) == r["final_size"], d
        _export_obs()
        if args.json:   # the perf-regression gate replays this payload
            os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            print(f"wrote smoke payload -> {args.json}")
        print("serving driver smoke OK")
        return
    cfg = DriverCfg(n=args.n, batch=args.batch, steps=args.steps,
                    warmup=args.warmup, queries=args.queries, k=args.k,
                    window=args.window, max_delay_ms=args.max_delay_ms,
                    seed=args.seed, mesh=args.mesh)
    if args.attributed:
        assert rec_obs is None, \
            "--attributed manages its own recorder; drop --obs-trace"
        assert mesh is None, \
            "--attributed compares obs on/off single-device; drop --mesh"
        scenario = args.scenarios.split(",")[0]
        payload = run_attributed(kinds=tuple(args.kinds.split(",")),
                                 scenario=scenario, cfg=cfg)
        os.makedirs(os.path.dirname(args.attributed) or ".",
                    exist_ok=True)
        with open(args.attributed, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"wrote attributed serve baseline -> {args.attributed}")
        return
    payload = run(kinds=args.kinds.split(","),
                  scenarios=args.scenarios.split(","), cfg=cfg, mesh=mesh)
    _export_obs()
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"wrote serving latency percentiles -> {args.json}")


if __name__ == "__main__":
    main()
