"""Workload driver: replay deterministic mixed update/query traces
through the serving runtime and report latency percentiles.

Per (backend, scenario) the driver builds a :class:`SpatialServer`
sized for the trace's peak live points, then replays the trace's steps
in the pipelined serving pattern:

1. take a snapshot of the current head version,
2. dispatch the step's delete + insert (async — versions ``v+1``,
   ``v+2`` go in flight; only the dispatch time is on the critical
   path),
3. answer the step's kNN and range requests **against the pre-step
   snapshot** through the :class:`MicroBatcher` (requests arrive as
   single-query submissions and coalesce into one pow2-padded batch per
   op — their device work overlaps the in-flight updates),
4. ``commit()`` — the only barrier; its wall time is the *exposed*
   update stall, i.e. whatever the queries did not hide.

Recorded ops: ``insert`` / ``delete`` (dispatch latency), ``knn`` /
``range`` (request submit -> result, including device wait), ``commit``
(exposed update stall). Warmup steps run the identical shapes first and
are dropped, so jit compiles and the query engine's pow2
bucket-escalation retraces never pollute a percentile (the
first-timed-batch skew the old ``launch/serve.py`` loop had).

Scenarios are ``repro.data.points.SCENARIOS``: churn over each point
distribution (uniform / sweepline / varden) plus the dynamic shapes
``moving-objects`` and ``sliding-window``.

Run:
  PYTHONPATH=src python -m repro.serving.driver --kinds porth,spac-h
  PYTHONPATH=src python -m repro.serving.driver --smoke
  PYTHONPATH=src python -m repro.serving.driver --json  # results/...
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from ..data import points as gen
from .batcher import MicroBatcher
from .metrics import LatencyRecorder
from .server import SpatialServer

DEFAULT_KINDS = ("porth", "spac-h")
DEFAULT_JSON = "results/serve_latency.json"


@dataclasses.dataclass(frozen=True)
class DriverCfg:
    n: int = 20_000           # bootstrap / live-set size
    batch: int = 512          # update batch per step
    steps: int = 6            # measured steps
    warmup: int = 2           # untimed steps (same shapes) dropped
    queries: int = 64         # kNN + range requests per step
    k: int = 10
    box_frac: int = 64        # range boxes span DEFAULT_HI / box_frac
    window: int = 4           # server version window
    # admission knob: high default so flushes are size-triggered (one
    # pow2 shape per op) and a timing-dependent split never compiles a
    # fresh shape inside the measured window; lower it to trade
    # throughput for per-request latency
    max_delay_ms: float = 50.0
    seed: int = 0
    dim: int = 2
    phi: int = 32


def _query_stream(cfg: DriverCfg, scenario: str, step: int):
    """Deterministic per-step query load: kNN points from the scenario's
    distribution (uniform for the dynamic shapes) + range boxes."""
    dist = scenario if scenario in gen.GENERATORS else "uniform"
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 7), step)
    k1, k2 = jax.random.split(key)
    qpts = gen.GENERATORS[dist](k1, cfg.queries, cfg.dim)
    lo, hi = gen.query_boxes(k2, cfg.queries, cfg.dim,
                             gen.DEFAULT_HI // cfg.box_frac)
    # requests arrive as host-side rows (as they would off the wire);
    # numpy slicing keeps per-submit overhead off the device
    return np.asarray(qpts), np.asarray(lo), np.asarray(hi)


def run_one(kind: str, scenario: str, cfg: DriverCfg,
            verbose: bool = False) -> dict:
    """Replay one (backend, scenario) trace; returns latency summary +
    sustained throughput for the measured window."""
    total = cfg.warmup + cfg.steps
    trace = gen.make_trace(scenario, seed=cfg.seed, n=cfg.n,
                           batch=cfg.batch, steps=total, dim=cfg.dim)
    t0 = time.perf_counter()
    srv = SpatialServer.build(kind, trace.bootstrap, phi=cfg.phi,
                              capacity_points=trace.max_live,
                              window=cfg.window)
    jax.block_until_ready(srv.head_index.tree)
    build_s = time.perf_counter() - t0
    batcher = MicroBatcher(max_batch=cfg.queries,
                           max_delay_s=cfg.max_delay_ms / 1e3)
    rec = LatencyRecorder()
    measured_updates = 0
    for s, step in enumerate(trace.steps):
        if s == cfg.warmup:
            rec.reset()   # drop warmup: compiles + bucket escalations
        snap = srv.snapshot()                       # pre-step version
        batcher.target = snap
        if step.delete is not None:
            with rec.timer("delete", step.delete.shape[0]):
                srv.delete(step.delete)             # async dispatch
        if step.insert is not None:
            with rec.timer("insert", step.insert.shape[0]):
                srv.insert(step.insert)             # async dispatch
        # micro-batched queries against the snapshot: single-query
        # requests coalesce into one pow2-padded engine call per op,
        # overlapping the in-flight updates on device
        qpts, lo, hi = _query_stream(cfg, scenario, s)
        t1 = time.perf_counter()
        knn_tickets = [batcher.submit_knn(qpts[i], cfg.k)
                       for i in range(cfg.queries)]
        jax.block_until_ready([t.result() for t in knn_tickets])
        rec.record("knn", time.perf_counter() - t1, cfg.queries)
        t1 = time.perf_counter()
        rng_tickets = [batcher.submit_range_count(lo[i], hi[i])
                       for i in range(cfg.queries)]
        jax.block_until_ready([t.result() for t in rng_tickets])
        rec.record("range", time.perf_counter() - t1, cfg.queries)
        with rec.timer("commit"):                   # exposed stall
            srv.commit()
        if s >= cfg.warmup:
            measured_updates += \
                (0 if step.delete is None else step.delete.shape[0]) + \
                (0 if step.insert is None else step.insert.shape[0])
    wall = rec.wall_s
    out = {
        "latency_ms": rec.latency_summary(),
        "throughput": {
            "query_per_s": rec.count("knn") + rec.count("range"),
            "update_pts_per_s": measured_updates,
            "wall_s": wall,
        },
        "build_s": build_s,
        "final_size": len(srv.head_index),
        "recoveries": srv.stats["recoveries"],
    }
    for key in ("query_per_s", "update_pts_per_s"):
        out["throughput"][key] = out["throughput"][key] / max(wall, 1e-9)
    if verbose:
        lat = out["latency_ms"]
        cells = " ".join(
            f"{op} p50={lat[op]['p50_ms']:7.2f} p99={lat[op]['p99_ms']:7.2f}"
            for op in ("insert", "delete", "knn", "range", "commit")
            if op in lat and lat[op]["count"])
        print(f"  [{kind}/{scenario}] {cells} | "
              f"{out['throughput']['query_per_s']:,.0f} q/s, "
              f"{out['throughput']['update_pts_per_s']:,.0f} upd-pts/s",
              flush=True)
    return out


def run(kinds=DEFAULT_KINDS, scenarios=gen.SCENARIOS,
        cfg: DriverCfg = DriverCfg(), verbose: bool = True) -> dict:
    """Sweep kinds x scenarios; returns the full json-able payload."""
    payload = {"config": dataclasses.asdict(cfg), "kinds": list(kinds),
               "scenarios": list(scenarios), "results": {}}
    for kind in kinds:
        if verbose:
            print(f"{kind}:", flush=True)
        payload["results"][kind] = {
            scenario: run_one(kind, scenario, cfg, verbose=verbose)
            for scenario in scenarios}
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kinds", default=",".join(DEFAULT_KINDS),
                    help="comma-separated registered backends")
    ap.add_argument("--scenarios", default=",".join(gen.SCENARIOS),
                    help=f"comma-separated from {gen.SCENARIOS}")
    ap.add_argument("--n", type=int, default=DriverCfg.n)
    ap.add_argument("--batch", type=int, default=DriverCfg.batch)
    ap.add_argument("--steps", type=int, default=DriverCfg.steps)
    ap.add_argument("--warmup", type=int, default=DriverCfg.warmup)
    ap.add_argument("--queries", type=int, default=DriverCfg.queries)
    ap.add_argument("--k", type=int, default=DriverCfg.k)
    ap.add_argument("--window", type=int, default=DriverCfg.window)
    ap.add_argument("--max-delay-ms", type=float,
                    default=DriverCfg.max_delay_ms)
    ap.add_argument("--seed", type=int, default=DriverCfg.seed)
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON, default=None,
                    metavar="PATH", help="write the latency/throughput "
                    f"payload (default {DEFAULT_JSON})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end trace for CI: one backend, "
                    "every scenario, seconds not minutes")
    args = ap.parse_args(argv)
    if args.smoke:
        cfg = DriverCfg(n=1500, batch=128, steps=2, warmup=1, queries=16,
                        k=5, seed=args.seed)
        payload = run(kinds=("spac-h",), scenarios=gen.SCENARIOS, cfg=cfg)
        ops = {op for r in payload["results"]["spac-h"].values()
               for op, s in r["latency_ms"].items() if s["count"]}
        assert {"insert", "delete", "knn", "range", "commit"} <= ops, ops
        print("serving driver smoke OK")
        return
    cfg = DriverCfg(n=args.n, batch=args.batch, steps=args.steps,
                    warmup=args.warmup, queries=args.queries, k=args.k,
                    window=args.window, max_delay_ms=args.max_delay_ms,
                    seed=args.seed)
    payload = run(kinds=args.kinds.split(","),
                  scenarios=args.scenarios.split(","), cfg=cfg)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"wrote serving latency percentiles -> {args.json}")


if __name__ == "__main__":
    main()
