"""Training launcher: real steps on whatever devices exist.

On the CPU container this trains reduced configs (examples/train_lm.py
drives it); on a TPU pod the same file runs the full config — the mesh
comes from launch.mesh and every sharding is mesh-shape-polymorphic.

Fault tolerance wiring (DESIGN.md Sec. 5): deterministic (seed, step)
data pipeline + atomic async checkpoints + FaultTolerantLoop (rollback
on loss spikes, retry on transient step failures, periodic snapshots).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.tokens import embedding_batch, lm_batch
from repro.ft import FaultTolerantLoop
from repro.optim.adamw import OptCfg
from repro.train.step import TrainCfg, init_train_state, make_train_step


def make_batches(cfg, seed: int, steps: int, batch: int, seq: int):
    for step in range(steps):
        toks, labels = lm_batch(seed, step, batch, seq, cfg.vocab)
        b = {"tokens": toks, "labels": labels}
        if cfg.kind == "encdec":
            b["prefix"] = embedding_batch(seed + 1, step, batch, seq // 2,
                                          cfg.frontend_dim)
        elif cfg.frontend is not None:
            b["prefix"] = embedding_batch(seed + 1, step, batch,
                                          cfg.frontend_seq,
                                          cfg.frontend_dim)
        yield step, b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.ARCHS[args.arch]
    cfg = cfg.with_(act_dtype="float32")   # CPU: f32 is faster & stabler
    tcfg = TrainCfg(n_microbatch=args.microbatch,
                    compress_grads=args.compress_grads,
                    opt=OptCfg(lr=args.lr, warmup_steps=10,
                               total_steps=args.steps))
    params, opt = init_train_state(jax.random.PRNGKey(args.seed), cfg, tcfg)
    start = 0
    if args.resume and args.ckpt_dir:
        from repro import ckpt
        (state, start) = ckpt.restore({"params": params, "opt": opt},
                                      args.ckpt_dir)
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    # contract: allow[uncached-jit] main() runs once per process; the
    # train step is jitted exactly once and reused for the whole loop
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    loop = FaultTolerantLoop(step_fn, ckpt_dir=args.ckpt_dir,
                             ckpt_every=10)

    t0 = time.time()
    losses = []

    def logging_step(p, o, b):
        p, o, m = step_fn(p, o, b)
        losses.append(float(m["loss"]))
        return p, o, m

    loop.train_step = logging_step
    params, opt = loop.run(
        (params, opt),
        make_batches(cfg, args.seed, args.steps, args.batch, args.seq),
        start_step=start)
    dt = time.time() - t0
    toks = args.batch * args.seq * (args.steps - start)
    print(f"{cfg.name}: {args.steps - start} steps, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"{toks / dt:,.0f} tok/s, retries={loop.retries} "
          f"rollbacks={loop.rollbacks}")
    if start == 0 and args.steps >= 20:
        assert losses[-1] < losses[0], "loss did not decrease"
    return losses


if __name__ == "__main__":
    main()
