"""Serving launcher: the paper's dynamic-index service + LM serving.

Two services behind one CLI:

  * ``--service index`` — the paper's workload as a long-running
    service: a dynamic spatial index absorbing batch updates while
    answering kNN/range queries (the end-to-end driver for deliverable
    (b); examples/dynamic_index_serving.py wraps this).
  * ``--service lm`` — batched LM serving (prefill + greedy decode) on
    a reduced config, exercising the same serve_step the dry-run lowers
    at production shapes.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --service index \
      --n 100000 --batches 20 --queries 1000
  PYTHONPATH=src python -m repro.launch.serve --service lm \
      --arch qwen1.5-0.5b --batch 4 --new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import make_index
from repro.data import points as gen
from repro.models import transformer
from repro.serve import ServeEngine


def serve_index(args):
    key = jax.random.PRNGKey(args.seed)
    n, m = args.n, args.n // args.batches
    pts = gen.GENERATORS[args.dist](key, n, 2)
    t0 = time.time()
    # serving mode: lifetime capacity up front, buffer donation per update,
    # jit-cached fixed-shape update closures (no retracing, no overflow
    # handling in the service loop)
    idx = make_index(args.kind, pts[: n // 2], phi=32, capacity_points=n,
                     donate=True).block_until_ready()
    t_build = time.time() - t0

    qk = jax.random.split(key, 3)
    qpts = gen.GENERATORS[args.dist](qk[0], args.queries, 2)
    box_lo, box_hi = gen.query_boxes(qk[1], args.queries, 2,
                                     gen.DEFAULT_HI // 16)
    ins_t = del_t = qry_t = rng_t = 0.0
    served = 0
    total_hits = 0
    for b in range((n // 2) // m):
        batch = pts[n // 2 + b * m: n // 2 + (b + 1) * m]
        t0 = time.time()
        idx = idx.insert(batch).block_until_ready()
        ins_t += time.time() - t0

        t0 = time.time()
        d2, ids = idx.knn(qpts, args.k)
        jax.block_until_ready(d2)
        qry_t += time.time() - t0

        # exact by construction: the engine sizes its own buffers, so
        # the served counts are trustworthy (pre-engine, `truncated`
        # was silently dropped here and answers could be short)
        t0 = time.time()
        cnt = idx.range_count(box_lo, box_hi)
        jax.block_until_ready(cnt)
        rng_t += time.time() - t0
        total_hits += int(cnt.sum())
        served += args.queries

        t0 = time.time()
        idx = idx.delete(batch[: m // 4]).block_until_ready()
        del_t += time.time() - t0

    print(f"index service [{args.dist}/{args.kind}] n={n}: "
          f"build {t_build:.2f}s | "
          f"insert {ins_t:.2f}s ({(n // 2) / ins_t:,.0f} pts/s) | "
          f"delete {del_t:.2f}s | {served} kNN in {qry_t:.2f}s "
          f"({served / qry_t:,.0f} q/s) | {served} range in {rng_t:.2f}s "
          f"({served / rng_t:,.0f} q/s, {total_hits} hits)")


def serve_lm(args):
    cfg = configs.smoke(args.arch).with_(act_dtype="float32")
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, max_len=args.prompt + args.new)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt), 0, cfg.vocab,
        dtype=jnp.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.new)
    jax.block_until_ready(out)
    dt = time.time() - t0
    print(f"lm serving [{cfg.name}]: batch={args.batch} prompt={args.prompt}"
          f" +{args.new} new -> {out.shape}, "
          f"{args.batch * args.new / dt:,.1f} tok/s")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", choices=["index", "lm"], default="index")
    ap.add_argument("--seed", type=int, default=0)
    # index service
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--dist", default="uniform",
                    choices=list(gen.GENERATORS))
    ap.add_argument("--kind", default="spac-h",
                    help="registered index backend (see repro.core)")
    # lm service
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args(argv)
    (serve_index if args.service == "index" else serve_lm)(args)


if __name__ == "__main__":
    main()
