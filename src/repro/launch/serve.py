"""Serving launcher: the paper's dynamic-index service + LM serving.

Two services behind one CLI:

  * ``--service index`` — a thin CLI over the versioned serving runtime
    (:mod:`repro.serving`): snapshot-isolated queries pipelined against
    async-dispatched updates, micro-batched through the QueryEngine's
    cached plans, with per-op p50/p95/p99 from the workload driver.
    The driver separates warmup from measured reps, so the reported
    percentiles exclude jit compiles and the engine's pow2
    bucket-escalation retraces (the old synchronous loop here timed
    both into its first batch).
  * ``--service lm`` — batched LM serving (prefill + greedy decode) on
    a reduced config, exercising the same serve_step the dry-run lowers
    at production shapes.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --service index \
      --n 100000 --batches 20 --queries 1000
  PYTHONPATH=src python -m repro.launch.serve --service lm \
      --arch qwen1.5-0.5b --batch 4 --new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import points as gen
from repro.models import transformer
from repro.serve import ServeEngine
from repro.serving import driver as serving_driver


def serve_index(args):
    """Replay the churn trace for (--dist, --kind) through the serving
    runtime; ``--scenario`` picks any other registered trace shape."""
    scenario = args.scenario or args.dist
    # churn bootstraps half of --n and streams in the rest; for the
    # dynamic shapes --n is the object/window count itself
    n = args.n // 2 if scenario in gen.GENERATORS else args.n
    cfg = serving_driver.DriverCfg(
        n=n, batch=max(args.n // (2 * args.batches), 16),
        steps=args.batches, warmup=min(2, max(args.batches // 2, 1)),
        queries=args.queries, k=args.k, seed=args.seed)
    payload = serving_driver.run(kinds=(args.kind,),
                                 scenarios=(scenario,), cfg=cfg,
                                 verbose=True)
    res = payload["results"][args.kind][scenario]
    thr = res["throughput"]
    print(f"index service [{scenario}/{args.kind}] n={args.n}: "
          f"build {res['build_s']:.2f}s | "
          f"{thr['query_per_s']:,.0f} q/s | "
          f"{thr['update_pts_per_s']:,.0f} update-pts/s | "
          f"final size {res['final_size']} | "
          f"recoveries {res['recoveries']}")


def serve_lm(args):
    cfg = configs.smoke(args.arch).with_(act_dtype="float32")
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, max_len=args.prompt + args.new)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt), 0, cfg.vocab,
        dtype=jnp.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.new)
    jax.block_until_ready(out)
    dt = time.time() - t0
    print(f"lm serving [{cfg.name}]: batch={args.batch} prompt={args.prompt}"
          f" +{args.new} new -> {out.shape}, "
          f"{args.batch * args.new / dt:,.1f} tok/s")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", choices=["index", "lm"], default="index")
    ap.add_argument("--seed", type=int, default=0)
    # index service
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--dist", default="uniform",
                    choices=list(gen.GENERATORS))
    ap.add_argument("--kind", default="spac-h",
                    help="registered index backend (see repro.core)")
    ap.add_argument("--scenario", default=None,
                    choices=list(gen.SCENARIOS),
                    help="trace shape (default: churn over --dist); "
                         "moving-objects / sliding-window etc.")
    # lm service
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args(argv)
    (serve_index if args.service == "index" else serve_lm)(args)


if __name__ == "__main__":
    main()
