"""Roofline terms from a compiled dry-run artifact (no hardware needed).

Per (arch x shape x mesh) cell:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs        [s]
  memory term     = HLO_bytes_per_device / HBM_bw            [s]
  collective term = collective_bytes_per_device / link_bw    [s]

cost_analysis() on a partitioned executable reports *per-device* flops
and bytes (verified numerically against hand counts). Collective bytes
are not in cost_analysis: we parse the post-SPMD HLO and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.

Target: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s+=\s+(\(?[^)=]*?\)?)\s+([\w\-]+)"
    r"(?:\.\d+)?\(([^)]*)\)")


def shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO type string: 'bf16[8,128]{1,0}' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind over the whole module.

    Returns {kind: bytes} + {"total": bytes, "count": n_instrs}.
    Operand shapes come from a first pass building name -> result type.
    """
    defs: dict[str, str] = {}
    instrs = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, operands = m.groups()
        defs[name.lstrip("%")] = rtype
        base = re.sub(r"\.\d+$", "", op)
        if base.endswith("-start"):
            base = base[:-6]
        if base in COLLECTIVE_OPS:
            instrs.append((base, rtype, operands))

    out = {k: 0 for k in COLLECTIVE_OPS}
    count = 0
    seen_done = set()
    for base, rtype, operands in instrs:
        count += 1
        b = 0
        for opnd in operands.split(","):
            nm = opnd.strip().lstrip("%").split(" ")[0]
            if nm in defs:
                b += shape_bytes(defs[nm])
        if b == 0:                      # fallback: result size
            b = shape_bytes(rtype)
        out[base] += b
        _ = seen_done
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    out["count"] = count
    return out


def terms(flops_per_dev: float, bytes_per_dev: float,
          coll_bytes_per_dev: float) -> dict:
    t = {
        "compute_s": flops_per_dev / PEAK_FLOPS,
        "memory_s": bytes_per_dev / HBM_BW,
        "collective_s": coll_bytes_per_dev / LINK_BW,
    }
    t["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
    return t


def active_param_count(cfg, shapes_tree=None) -> tuple[int, int]:
    """(N_total, N_active): MoE expert weights scale by top_k/n_experts;
    the embedding *lookup* table is excluded from N (0 matmul flops) but
    the tied unembed projection (D*V) is counted."""
    import jax

    from repro.models import encdec, transformer
    if shapes_tree is None:
        init = (encdec.init_params if cfg.kind == "encdec"
                else transformer.init_params)
        shapes_tree = jax.eval_shape(
            lambda: init(jax.random.PRNGKey(0), cfg))
    flat = jax.tree_util.tree_flatten_with_path(shapes_tree)[0]
    total = active = 0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        total += leaf.size
        if "embed" in keys:
            continue
        if cfg.moe is not None and leaf.ndim == 4 \
                and leaf.shape[1] == cfg.moe.n_experts:
            active += leaf.size * cfg.moe.top_k // cfg.moe.n_experts
        else:
            active += leaf.size
    active += cfg.d_model * cfg.vocab    # tied unembed matmul
    return total, active


def model_flops(cfg, n_tokens: int, mode: str) -> float:
    """6*N_active*tokens for train (fwd+bwd), 2*N_active*tokens for
    forward-only (prefill/decode)."""
    _, n_active = active_param_count(cfg)
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_active * n_tokens


def expert_param_count(cfg) -> int:
    """Total parameters living inside MoE expert weights."""
    import jax

    from repro.models import transformer
    if cfg.moe is None:
        return 0
    shapes_tree = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    n = 0
    for leaf in jax.tree.leaves(shapes_tree):
        if leaf.ndim == 4 and leaf.shape[1] == cfg.moe.n_experts:
            n += leaf.size
    return n


# ------------------------------------------------- analytic HBM traffic

def memory_traffic(cfg, shape, n_chips: int, tp: int = 16,
                   n_micro: int = 1, moment_bytes: int = 4) -> dict:
    """Analytic per-device HBM traffic (bytes/step).

    Why analytic: CPU-lowered HLO puts every elementwise op in its own
    fusion, so fusion-boundary byte counting over-reports TPU traffic
    ~100x (TPU fuses those chains into dot epilogues). This model counts
    the traffic a tuned TPU execution cannot avoid; per-component terms
    are returned so §Perf can attack the dominant one. HLO-derived bytes
    remain in the dry-run record for *relative* A/B comparison.

    Components (bf16 activations/params, f32 scores):
      weights   — FSDP-gathered weight reads: fwd + bwd re-gather, per
                  microbatch; decode/prefill read once. MoE: only
                  touched experts are read on decode.
      opt       — m/v read+write + master param update (train only)
      grads     — accumulator write+read (train only)
      act       — remat-boundary saves: n_groups x tokens x D x 2B,
                  write fwd + read bwd (+ recompute stream ~4 buffers
                  per layer visit)
      scores    — attention p-matrix traffic (f32), causal-halved;
                  windowed archs clamp kv extent to the window
      kv        — decode: full cache read per step / tp shards;
                  prefill: cache write
      logits    — vocab-projection activations (loss-chunked)
    """
    dp = n_chips // tp
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(B // dp, 1)
    n_total, _ = active_param_count(cfg)
    p_bytes = n_total * 2                       # bf16 weights
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    D = cfg.d_model
    G = cfg.n_groups

    n_attn = sum(1 for j in range(cfg.n_layers) if cfg.layer_type(j) == "a")
    if cfg.kind == "encdec":
        n_attn = cfg.encoder_layers + 2 * cfg.n_layers

    t = {}
    if shape.mode == "train":
        tok_loc = b_loc * S
        tok_micro = tok_loc // n_micro
        t["weights"] = 2.0 * n_micro * p_bytes / tp
        n_opt = n_total
        t["opt"] = 3.0 * 2 * n_opt * moment_bytes / n_chips
        t["grads"] = 2.0 * n_total * 4 / n_chips
        boundary = G * tok_micro * D * 2
        recompute = cfg.n_layers * tok_micro * D * 2 * 4
        t["act"] = n_micro * (2.0 * boundary + 3.0 * recompute)
        kv_extent = min(cfg.window or S, S)
        s_frac = 0.5 if cfg.window is None else \
            (1.0 - kv_extent / (2 * S))
        p_elems = (b_loc // n_micro) * Hq * S * kv_extent * s_frac \
            / (tp if Hq % tp == 0 else 1)
        t["scores"] = n_micro * n_attn * p_elems * 4 * 5.0   # fwd2+bwd3
        t["logits"] = 3.0 * tok_loc * cfg.vocab // tp * 2
        t["kv"] = 0.0
    elif shape.mode == "prefill":
        tok_loc = b_loc * S
        t["weights"] = p_bytes / tp
        t["opt"] = t["grads"] = 0.0
        t["act"] = cfg.n_layers * tok_loc * D * 2 * 4
        kv_extent = min(cfg.window or S, S)
        s_frac = 0.5 if cfg.window is None else \
            (1.0 - kv_extent / (2 * S))
        p_elems = b_loc * Hq * S * kv_extent * s_frac \
            / (tp if Hq % tp == 0 else 1)
        t["scores"] = n_attn * p_elems * 4 * 2.0
        t["kv"] = n_attn * b_loc * Hkv * min(cfg.window or S, S) * hd \
            * 2 * 2 / tp
        t["logits"] = b_loc * cfg.vocab // tp * 2
    else:  # decode: one token, cache resident
        if cfg.moe is not None:
            # only routed experts load: min(E, B*topk) distinct
            touched = min(cfg.moe.n_experts, B * cfg.moe.top_k)
            frac = touched / cfg.moe.n_experts
            n_exp = expert_param_count(cfg)
            t["weights"] = ((n_total - n_exp) + n_exp * frac) * 2 / tp
        else:
            t["weights"] = p_bytes / tp
        t["opt"] = t["grads"] = t["act"] = t["scores"] = 0.0
        kv_extent = min(cfg.window or S, S)
        seq_shard = tp if B >= dp else n_chips
        t["kv"] = n_attn * b_loc * Hkv * kv_extent * hd * 2 / seq_shard
        t["logits"] = b_loc * cfg.vocab // tp * 2
    t["total"] = sum(v for k, v in t.items())
    return t
