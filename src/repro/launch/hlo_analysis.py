"""Static analysis of post-SPMD HLO text: FLOPs / bytes / collectives.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body
ONCE — a 94-layer lax.scan under-reports FLOPs ~94x, making rooflines
garbage. This walker parses the partitioned HLO module, extracts loop
trip counts from each while's condition computation (compare(iv, N),
direction=LT), and multiplies body costs through arbitrary nesting.

Reported, all per-device (the module is the per-device program):
  * flops            — dot/convolution ops: 2 * numel(result) * K
                       (elementwise flops ignored: MXU dots dominate)
  * bytes            — fusion-boundary traffic model: sum of operand +
                       result buffer sizes over every materializing
                       instruction (fusions, dots, copies, collectives,
                       gathers/scatters, ...). An upper-ish proxy for
                       HBM traffic under XLA's one-buffer-per-fusion
                       execution; exact enough to rank bottlenecks.
  * collectives      — operand bytes per collective kind, loop-scaled.

Verified against hand counts on sharded toy programs
(tests/test_hlo_analysis.py).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# opcodes that don't touch buffers / are aliases
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "opt-barrier", "partition-id",
             "replica-id", "custom-call"}


def shape_dims(shape_str: str):
    """All (dtype, dims) groups in an HLO type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        out.append((dt, d))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    rtype: str
    opcode: str
    operands: list[str]
    raw: str


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"          # name
    r"((?:\([^)]*\)|[\w\[\],{}\s]+?))\s+"            # result type
    r"([\w\-]+)"                                       # opcode
    r"\(([^)]*)\)"                                     # operands
    r"(.*)$")                                          # attrs


def _operand_names(s: str):
    names = []
    depth = 0
    cur = []
    for ch in s:
        if ch == "(" or ch == "[" or ch == "{":
            depth += 1
        elif ch == ")" or ch == "]" or ch == "}":
            depth -= 1
        if ch == "," and depth == 0:
            names.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        names.append("".join(cur).strip())
    out = []
    for n in names:
        n = n.strip().lstrip("%")
        # strip inline type annotations like "f32[8] %foo"
        parts = n.split("%")
        n = parts[-1] if len(parts) > 1 else n
        n = n.split(" ")[0].split(")")[0]
        if n:
            out.append(n)
    return out


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, dict[str, Instr]] = {}
        self.entry: str | None = None
        cur = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = {}
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR.match(line)
            if m:
                name, rtype, opcode, operands, attrs = m.groups()
                self.comps[cur][name] = Instr(
                    name, rtype.strip(), opcode, _operand_names(operands),
                    line)
        if self.entry is None and self.comps:
            self.entry = list(self.comps)[-1]

    # -------------------------------------------------------- helpers

    def _attr(self, instr: Instr, key: str):
        m = re.search(key + r"=%?([\w.\-]+)", instr.raw)
        return m.group(1) if m else None

    def _attr_list(self, instr: Instr, key: str):
        m = re.search(key + r"={([\d,]*)}", instr.raw)
        if not m:
            return []
        return [int(x) for x in m.group(1).split(",") if x]

    def _group_size(self, instr: Instr) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.raw)
        if m:
            return max(int(m.group(2)), 1)
        m = re.search(r"replica_groups={{([\d,]+)}", instr.raw)
        if m:
            return max(len(m.group(1).split(",")), 1)
        return 2   # unknown: assume smallest nontrivial group

    def operand_type(self, comp: str, name: str) -> str:
        ins = self.comps[comp].get(name)
        return ins.rtype if ins is not None else ""

    def _has_lt_compare(self, comp: str) -> bool:
        return any(i.opcode == "compare" and "direction=LT" in i.raw
                   for i in self.comps.get(comp, {}).values())

    def trip_count(self, instr: Instr) -> int:
        """Extract N from the while condition: compare(iv, const N), LT.
        The compare may be wrapped in a kLoop fusion (XLA:CPU) with the
        constant passed in as a fusion operand."""
        cond = self._attr(instr, "condition")
        if cond is None or cond not in self.comps:
            return 1
        consts = {}
        for ins in self.comps[cond].values():
            if ins.opcode == "constant":
                m = re.search(r"constant\((-?\d+)\)", ins.raw)
                if m:
                    consts[ins.name] = int(m.group(1))
        for ins in self.comps[cond].values():
            direct = (ins.opcode == "compare"
                      and "direction=LT" in ins.raw)
            fused = (ins.opcode == "fusion"
                     and self._has_lt_compare(self._attr(ins, "calls")))
            if direct or fused:
                for op in ins.operands:
                    if op in consts:
                        return max(consts[op], 1)
        return 1

    # ---------------------------------------------------------- costs

    def _dot_flops(self, comp: str, instr: Instr) -> float:
        out_elems = 1
        groups = shape_dims(instr.rtype)
        if not groups:
            return 0.0
        for d in groups[0][1]:
            out_elems *= d
        lhs_t = self.operand_type(comp, instr.operands[0]) \
            if instr.operands else ""
        lhs_dims = shape_dims(lhs_t)
        k = 1
        if lhs_dims:
            cdims = self._attr_list(instr, "lhs_contracting_dims")
            for c in cdims:
                if c < len(lhs_dims[0][1]):
                    k *= lhs_dims[0][1][c]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: str, instr: Instr) -> float:
        out_elems = 1
        groups = shape_dims(instr.rtype)
        if not groups:
            return 0.0
        for d in groups[0][1]:
            out_elems *= d
        rhs_t = self.operand_type(comp, instr.operands[1]) \
            if len(instr.operands) > 1 else ""
        rd = shape_dims(rhs_t)
        k = 1
        if rd:
            n = 1
            for d in rd[0][1]:
                n *= d
            # kernel elems / output-feature dim ~ per-output MACs
            k = max(n // max(groups[0][1][-1], 1), 1)
        return 2.0 * out_elems * k

    def analyze(self, comp: str | None = None, _depth: int = 0,
                _scale: float = 1.0, detail: list | None = None) -> dict:
        """detail: optional list collecting (kind, scaled_bytes, op_name)
        per collective instruction — the §Perf drill-down."""
        comp = comp or self.entry
        res = {"flops": 0.0, "bytes": 0.0, "wire_bytes": 0.0,
               **{k: 0.0 for k in COLLECTIVE_OPS}}
        if comp not in self.comps or _depth > 50:
            return res
        for instr in self.comps[comp].values():
            op = instr.opcode
            base = re.sub(r"-(start|done)$", "", op)
            if op == "while":
                trips = self.trip_count(instr)
                body = self._attr(instr, "body")
                sub = self.analyze(body, _depth + 1, _scale * trips,
                                   detail)
                for k in res:
                    res[k] += sub[k] * trips
                continue
            if op in ("call", "async-call"):
                target = self._attr(instr, "to_apply")
                if target:
                    sub = self.analyze(target, _depth + 1, _scale, detail)
                    for k in res:
                        res[k] += sub[k]
                continue
            if op == "conditional":
                branches = re.findall(r"branch_computations={([^}]*)}",
                                      instr.raw)
                subs = []
                if branches:
                    for b in branches[0].split(","):
                        subs.append(self.analyze(b.strip().lstrip("%"),
                                                 _depth + 1))
                for k in res:
                    res[k] += max((s[k] for s in subs), default=0.0)
                continue
            if op == "fusion":
                called = self._attr(instr, "calls")
                if called:
                    sub = self.analyze(called, _depth + 1, _scale, detail)
                    res["flops"] += sub["flops"]     # dots inside fusions
                    for c in COLLECTIVE_OPS:
                        res[c] += sub[c]
            if op == "dot":
                res["flops"] += self._dot_flops(comp, instr)
            elif op == "convolution":
                res["flops"] += self._conv_flops(comp, instr)
            if base in COLLECTIVE_OPS and not op.endswith("-done"):
                b = 0
                for o in instr.operands:
                    t = self.operand_type(comp, o)
                    b += shape_bytes(t)
                if b == 0:
                    b = shape_bytes(instr.rtype)
                res[base] += b
                # wire bytes: what actually crosses links per device.
                # ring all-reduce moves 2(n-1)/n x operand; all-gather
                # receives (n-1) x shard; reduce-scatter/all-to-all move
                # (n-1)/n x operand; permute moves the operand once.
                n = self._group_size(instr)
                f = {"all-reduce": 2.0 * (n - 1) / n,
                     "all-gather": float(n - 1),
                     "reduce-scatter": (n - 1) / n,
                     "all-to-all": (n - 1) / n,
                     "collective-permute": 1.0}[base]
                res["wire_bytes"] += b * f
                if detail is not None:
                    m = re.search(r'op_name="([^"]*)"', instr.raw)
                    detail.append((base, b * _scale,
                                   m.group(1) if m else instr.name))
            # fusion-boundary byte traffic
            if op not in _FREE_OPS and not op.endswith("-done"):
                b = shape_bytes(instr.rtype)
                for o in instr.operands:
                    b += shape_bytes(self.operand_type(comp, o))
                res["bytes"] += b
        return res


def analyze_text(hlo_text: str, detail: bool = False) -> dict:
    mod = HloModule(hlo_text)
    det: list | None = [] if detail else None
    out = mod.analyze(detail=det)
    out["collective_bytes"] = sum(out[k] for k in COLLECTIVE_OPS)
    if detail:
        det.sort(key=lambda t: -t[1])
        out["detail"] = det
    return out
