from repro.configs import platform as _platform

_platform.stage(host_device_count=512)

# Staging MUST run before any jax-touching import: jax locks the device
# count at first backend init, and the production meshes need 512
# placeholder host devices. repro.configs.platform composes with an
# existing XLA_FLAGS (a user's other flags survive) and raises early if
# the backend already initialized with a different topology. Never set
# this globally — smoke tests and benchmarks see the real single device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the *real* jitted program (train_step
or serve prefill/decode step) with production in/out shardings over
ShapeDtypeStruct stand-ins — no arrays are ever allocated — then:

    lowered  = jax.jit(fn, in_shardings=..., out_shardings=...,
                       donate_argnums=...).lower(*specs)
    compiled = lowered.compile()
    compiled.memory_analysis()   # proves it fits per-device HBM
    compiled.cost_analysis()     # per-device FLOPs/bytes for §Roofline

plus a post-SPMD HLO pass summing collective operand bytes
(launch.roofline). Failures here (sharding mismatch, OOM at compile,
unsupported collective) are bugs in the framework, not in the run.

Usage:
  python -m repro.launch.dryrun --arch all --mesh both --out results/
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k \
      --set attn_causal_prune=False        # baseline A/B for §Perf
"""

import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis, roofline
from repro.launch.mesh import make_production_mesh
from repro.models import encdec, transformer
from repro.models.config import SHAPES, ModelCfg
from repro.optim.adamw import adamw_init
from repro.sharding import constraints, rules
from repro.train.step import TrainCfg, make_train_step

# Per-arch training knobs: microbatching bounds the remat-boundary
# activations (G x B x S x D per device); bf16 moments are required to
# fit 398B-class optimizer state on one pod (EXPERIMENTS.md §Dry-run).
TRAIN_OVERRIDES: dict[str, dict] = {
    # microbatch counts assume the SP (sequence-parallel) scan-carry
    # boundary: remat saves are S/tp per device, so far fewer
    # microbatches fit — which divides the per-microbatch gradient
    # reduce traffic (EXPERIMENTS.md §Perf). jamba keeps bf16
    # moments/accum: 398B f32 state cannot fit one pod.
    "jamba-1.5-large-398b": dict(n_microbatch=2, moment_dtype="bfloat16",
                                 accum_dtype="bfloat16"),
    "qwen3-moe-235b-a22b": dict(n_microbatch=4),
    "phi3.5-moe-42b-a6.6b": dict(n_microbatch=2),
    "command-r-35b": dict(n_microbatch=2),
    "internvl2-26b": dict(n_microbatch=2),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _rep(mesh):
    return NamedSharding(mesh, P())


def batch_specs(cfg: ModelCfg, shape, dtype="int32"):
    """ShapeDtypeStructs + PartitionSpecs for one training batch."""
    import jax.numpy as jnp
    B, S = shape.global_batch, shape.seq_len
    if cfg.kind == "encdec":
        Se = Sd = S // 2
        sds = dict(prefix=_sds((B, Se, cfg.frontend_dim), jnp.float32),
                   tokens=_sds((B, Sd), jnp.int32),
                   labels=_sds((B, Sd), jnp.int32))
    elif cfg.frontend is not None:
        Pn = cfg.frontend_seq
        sds = dict(prefix=_sds((B, Pn, cfg.frontend_dim), jnp.float32),
                   tokens=_sds((B, S - Pn), jnp.int32),
                   labels=_sds((B, S - Pn), jnp.int32))
    else:
        sds = dict(tokens=_sds((B, S), jnp.int32),
                   labels=_sds((B, S), jnp.int32))
    return sds


# ----------------------------------------------------------- cell build

def build_train(cfg: ModelCfg, shape, mesh):
    tcfg = TrainCfg(**TRAIN_OVERRIDES.get(cfg.name, {}))
    step = make_train_step(cfg, tcfg)

    init = (encdec.init_params if cfg.kind == "encdec"
            else transformer.init_params)
    params_sds = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))
    import jax.numpy as jnp
    opt_sds = jax.eval_shape(
        lambda: adamw_init(params_sds, jnp.dtype(tcfg.moment_dtype)))
    bsds = batch_specs(cfg, shape)

    pspecs = rules.param_specs(cfg, mesh)
    zspecs = rules.zero1_specs(pspecs, params_sds, mesh)
    ospecs = {"m": zspecs, "v": zspecs, "step": P()}
    dspecs = rules.data_specs(mesh, shape.global_batch)
    bspecs = {k: dspecs[k] for k in bsds}

    ps, osh, bs = (_shardings(mesh, t) for t in (pspecs, ospecs, bspecs))
    mets = {"lr": _rep(mesh), "grad_norm": _rep(mesh), "loss": _rep(mesh)}
    # contract: allow[uncached-jit] one-shot launcher: a dry run builds
    # this jit exactly once per process, so closure caching buys nothing
    fn = jax.jit(step, in_shardings=(ps, osh, bs),
                 out_shardings=(ps, osh, mets), donate_argnums=(0, 1))
    n_tokens = shape.global_batch * shape.seq_len
    return fn, (params_sds, opt_sds, bsds), n_tokens


def build_prefill(cfg: ModelCfg, shape, mesh):
    import jax.numpy as jnp
    B, S = shape.global_batch, shape.seq_len
    pspecs = rules.param_specs(cfg, mesh, mode="serve")
    ps = _shardings(mesh, pspecs)
    dspecs = rules.data_specs(mesh, B)
    dp = dspecs["tokens"]

    init = (encdec.init_params if cfg.kind == "encdec"
            else transformer.init_params)
    params_sds = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))

    if cfg.kind == "encdec":
        Se = Sd = S // 2
        frames = _sds((B, Se, cfg.frontend_dim), jnp.float32)
        toks = _sds((B, Sd), jnp.int32)

        def fn(params, frames, tokens):
            return encdec.prefill(params, frames, tokens, cfg, max_len=Sd)

        cspec = rules.encdec_cache_specs(cfg, mesh, B)
        inp_sds = (params_sds, frames, toks)
        inp_sh = (ps, _shardings(mesh, dspecs["prefix"]),
                  _shardings(mesh, dp))
    else:
        Pn = cfg.frontend_seq if cfg.frontend is not None else 0
        toks = _sds((B, S - Pn), jnp.int32)
        pre = (_sds((B, Pn, cfg.frontend_dim), jnp.float32)
               if Pn else None)

        def fn(params, tokens, prefix=None):
            return transformer.prefill(params, tokens, cfg, max_len=S,
                                       prefix_embed=prefix)

        cspec = rules.cache_specs(cfg, mesh, B)
        if Pn:
            inp_sds = (params_sds, toks, pre)
            inp_sh = (ps, _shardings(mesh, dp),
                      _shardings(mesh, dspecs["prefix"]))
        else:
            inp_sds = (params_sds, toks)
            inp_sh = (ps, _shardings(mesh, dp))

    vax = rules.TP if cfg.vocab % mesh.shape[rules.TP] == 0 else None
    logits_sh = _shardings(mesh, P(rules.batch_axes(mesh) or None, None,
                                   vax))
    out_sh = (logits_sh, _shardings(mesh, cspec))
    # contract: allow[uncached-jit] one-shot launcher (see build_train)
    jfn = jax.jit(fn, in_shardings=inp_sh, out_shardings=out_sh)
    n_tokens = B * S
    return jfn, inp_sds, n_tokens


def build_decode(cfg: ModelCfg, shape, mesh):
    import jax.numpy as jnp
    B, S = shape.global_batch, shape.seq_len
    pspecs = rules.param_specs(cfg, mesh, mode="serve")
    ps = _shardings(mesh, pspecs)
    dspecs = rules.data_specs(mesh, B)
    tok = _sds((B, 1), jnp.int32)

    init = (encdec.init_params if cfg.kind == "encdec"
            else transformer.init_params)
    params_sds = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))

    if cfg.kind == "encdec":
        cache_sds = jax.eval_shape(
            lambda: encdec.init_cache(cfg, B, S, S // 2))
        cspec = rules.encdec_cache_specs(cfg, mesh, B)

        def fn(params, cache, tok):
            return encdec.decode_step(params, cache, tok, cfg)
    else:
        cache_sds = jax.eval_shape(lambda: transformer.init_cache(cfg, B, S))
        cspec = rules.cache_specs(cfg, mesh, B)

        def fn(params, cache, tok):
            return transformer.decode_step(params, cache, tok, cfg)

    b_axes = rules.batch_axes(mesh)
    n_dp = 1
    for a in b_axes:
        n_dp *= mesh.shape[a]
    baxis = b_axes if B % n_dp == 0 else None
    vax = rules.TP if cfg.vocab % mesh.shape[rules.TP] == 0 else None
    logits_sh = _shardings(mesh, P(baxis, None, vax))
    cs = _shardings(mesh, cspec)
    # contract: allow[uncached-jit] one-shot launcher (see build_train)
    jfn = jax.jit(fn, in_shardings=(ps, cs, _shardings(mesh,
                                                       P(baxis, None))),
                  out_shardings=(logits_sh, cs), donate_argnums=(1,))
    return jfn, (params_sds, cache_sds, tok), B


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


# ------------------------------------------------------------- run cell

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, want_hlo: bool = False):
    cfg = configs.ARCHS[arch]
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    # ambient mesh: activation sharding constraints in model code
    # (sharding/constraints.py) resolve against it
    constraints.set_ambient_mesh(mesh)
    rec = dict(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               mode=shape.mode, ok=False)
    try:
        fn, inp, n_tokens = BUILDERS[shape.mode](cfg, shape, mesh)
        t0 = time.time()
        lowered = fn.lower(*inp)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec.update(lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2))

        ca = compiled.cost_analysis() or {}
        rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float))
                           and k in ("flops", "bytes accessed")}

        ma = compiled.memory_analysis()
        mem = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "peak_memory_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
        rec["memory"] = mem

        hlo = compiled.as_text()
        res = hlo_analysis.analyze_text(hlo, detail=want_hlo)
        flops, byts = res["flops"], res["bytes"]
        rec["collectives"] = {k: res[k]
                              for k in hlo_analysis.COLLECTIVE_OPS}
        rec["collectives"]["total"] = res["collective_bytes"]
        rec["hlo_lines"] = hlo.count("\n")

        n_chips = mesh.size
        ov = TRAIN_OVERRIDES.get(cfg.name, {})
        mt = roofline.memory_traffic(
            cfg, shape, n_chips, tp=mesh.shape["model"],
            n_micro=ov.get("n_microbatch", 1),
            moment_bytes=2 if ov.get("moment_dtype") == "bfloat16" else 4)
        mf = roofline.model_flops(cfg, n_tokens, shape.mode)
        rec["flops_per_dev"] = flops
        rec["hlo_bytes_cpu_fusion"] = byts   # relative A/B diagnostic
        rec["mem_traffic"] = {k: round(v) for k, v in mt.items()}
        rec["model_flops_per_dev"] = mf / n_chips
        rec["useful_frac"] = (mf / n_chips) / flops if flops else 0.0
        rec["terms"] = roofline.terms(flops, mt["total"],
                                      res["collective_bytes"])
        rec["n_tokens"] = n_tokens
        rec["ok"] = True
        if want_hlo:
            rec["detail"] = [
                (k, b, n) for k, b, n in res.get("detail", [])[:40]]
    except Exception as e:  # noqa: BLE001 — record, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    return rec


def fmt_cell(rec: dict) -> str:
    if not rec["ok"]:
        return (f"FAIL {rec['arch']:26s} {rec['shape']:12s} {rec['mesh']:8s}"
                f" {rec['error'][:90]}")
    t = rec["terms"]
    mem_gb = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
    return (f"ok   {rec['arch']:26s} {rec['shape']:12s} {rec['mesh']:8s} "
            f"comp={t['compute_s']:9.3e} mem={t['memory_s']:9.3e} "
            f"coll={t['collective_s']:9.3e} dom={t['bottleneck'][:-2]:10s} "
            f"useful={rec['useful_frac']:6.1%} state={mem_gb:7.2f}GiB "
            f"[lower {rec['lower_s']}s compile {rec['compile_s']}s]")


def parse_set(kvs):
    out = {}
    for kv in kvs or []:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default=None, help="JSONL output path")
    ap.add_argument("--set", action="append", dest="sets", metavar="K=V",
                    help="ModelCfg field overrides (perf A/B)")
    args = ap.parse_args()

    archs = list(configs.ARCHS) if args.arch == "all" else [args.arch]
    overrides = parse_set(args.sets)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out_f = open(args.out, "a") if args.out else None
    n_fail = 0
    for arch in archs:
        shapes = (configs.cells(arch) if args.shape == "all"
                  else [args.shape])
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, overrides)
                print(fmt_cell(rec), flush=True)
                n_fail += 0 if rec["ok"] else 1
                if out_f:
                    rec.pop("traceback", None)
                    out_f.write(json.dumps(rec) + "\n")
                    out_f.flush()
    if out_f:
        out_f.close()
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
