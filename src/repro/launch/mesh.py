"""Production meshes.

Functions, not module constants: importing this module never touches
jax device state (device count locks on first jax init — the dry-run
must set XLA_FLAGS before anything else).

Single pod: (16, 16) = ("data", "model") — 256 v5e chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — the "pod" axis is
pure DP + ZeRO over DCN; all TP/EP/SP collectives stay inside a pod's
ICI. At 1000+ nodes the pod axis simply grows (4, 8, ... pods): no
code change, the axis is already rank-polymorphic in every spec.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
