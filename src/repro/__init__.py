"""Psi-JAX: reproduction of "Parallel Dynamic Spatial Indexes" in JAX.

Subpackages (imported explicitly; nothing is pulled in eagerly here):

  * ``repro.core``  -- the spatial indexes + the unified Index API
  * ``repro.data``  -- synthetic workloads, batch streams, update traces
  * ``repro.serving`` -- versioned spatial serving runtime (snapshots,
    micro-batching, latency-percentile workload driver)
  * ``repro.kernels`` / ``repro.launch`` / ``repro.serve`` -- accelerator
    kernels, launch tooling, and the LM serving engine
"""

__version__ = "0.1.0"
