"""Recorder core: spans, counters, gauges, pow2 histograms, and the
deferred device-read list.

Everything here is host-side Python over stdlib types — the subsystem
is zero-dependency by design (``jax`` is touched only inside
:meth:`Recorder.resolve`, the one sanctioned sync point) so enabling it
can never change what the instrumented code compiles or dispatches.

Two invariants this module owns (see ROADMAP "Observability"):

* **No host sync off the barrier.** Instrumented code may *attach*
  in-flight device values to a span (:meth:`Span.defer`) or to a named
  counter (:meth:`Recorder.add_deferred`) — both are list appends. The
  host read happens only in :meth:`Recorder.resolve`, which callers
  invoke at an existing barrier (``SpatialServer.commit``, report
  time). This mirrors the serving runtime's sticky-``overflowed``
  pattern: the flag rides along on device and one read at the sync
  point covers everything since. The ``obs-deferred-sync`` lint rule
  enforces it over this package.
* **Disabled mode is near-free.** The module-level helpers in
  :mod:`repro.obs` check one dict slot and return a shared no-op span
  when no recorder is installed; nothing is allocated and no clock is
  read (asserted by the overhead microtest in tests/test_obs.py).

Histograms bucket observations by power of two (bucket key = the
smallest ``2**e`` >= value, 0 for 0) — the same pow2 shape the engine's
buffer escalation and the batcher's padding already quantize to — and
additionally retain up to ``max_samples`` raw samples so report-time
percentiles (p50/p95/p99) are exact for bounded runs like the workload
driver's.
"""

from __future__ import annotations

import math
import threading
import time


def pow2_bucket(value) -> float:
    """Upper edge of the power-of-two bucket holding ``value``:
    smallest ``2.0**e`` >= value (0.0 for values <= 0)."""
    v = float(value)
    if v <= 0.0:
        return 0.0
    m, e = math.frexp(v)          # v = m * 2**e, 0.5 <= m < 1
    return float(2.0 ** (e - 1 if m == 0.5 else e))


def percentile(sorted_samples, p: float) -> float:
    """Nearest-rank percentile over an already-sorted list."""
    n = len(sorted_samples)
    if not n:
        return 0.0
    rank = min(n - 1, max(0, math.ceil(p / 100.0 * n) - 1))
    return float(sorted_samples[rank])


class Hist:
    """Pow2-bucket histogram with bounded raw-sample retention."""

    __slots__ = ("buckets", "samples", "count", "total", "min", "max",
                 "max_samples", "dropped")

    def __init__(self, max_samples: int = 8192):
        self.buckets: dict[float, int] = {}
        self.samples: list[float] = []
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.max_samples = max_samples
        self.dropped = 0              # samples past retention (buckets
                                      # still count them)

    def observe(self, value) -> None:
        v = float(value)
        b = pow2_bucket(v)
        self.buckets[b] = self.buckets.get(b, 0) + 1
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if len(self.samples) < self.max_samples:
            self.samples.append(v)
        else:
            self.dropped += 1

    def summary(self, scale: float = 1.0) -> dict:
        """count/mean/min/max plus p50/p95/p99 — exact from retained
        samples, falling back to bucket upper edges past retention."""
        if not self.count:
            return {"count": 0}
        out = {"count": self.count,
               "mean": scale * self.total / self.count,
               "min": scale * self.min, "max": scale * self.max}
        if self.dropped:
            # percentile from bucket edges (upper bounds -> pessimistic)
            edges = sorted(self.buckets)
            cum, spread = 0, []
            for e in edges:
                spread.extend([e] * self.buckets[e])
            samples = spread
        else:
            samples = sorted(self.samples)
        for p in (50.0, 95.0, 99.0):
            out[f"p{p:g}"] = scale * percentile(samples, p)
        return out

    def to_dict(self) -> dict:
        return {"buckets": {repr(k): v
                            for k, v in sorted(self.buckets.items())},
                **self.summary()}


class Span:
    """One timed section. Use as a context manager (the common path) or
    drive ``begin()``/``end()`` by hand. ``set()`` adds attributes;
    ``defer()`` attaches an in-flight device value whose host read is
    postponed to the owning recorder's :meth:`Recorder.resolve`."""

    __slots__ = ("rec", "name", "cat", "args", "t0", "dur")

    def __init__(self, rec: "Recorder", name: str, cat: str, args: dict):
        self.rec = rec
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = None
        self.dur = None

    def __enter__(self) -> "Span":
        self.t0 = self.rec.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.end()
        return False

    def begin(self) -> "Span":
        return self.__enter__()

    def end(self) -> None:
        self.dur = self.rec.clock() - self.t0
        self.rec._finish(self)

    def set(self, **attrs) -> "Span":
        self.args.update(attrs)
        return self

    def defer(self, key: str, value) -> "Span":
        """Attach an in-flight device value; ``args[key]`` is filled in
        (plus ``<key>_resolved_s``, the barrier-side completion stamp)
        at the recorder's next ``resolve()``. Never reads the value."""
        # placeholder keeps args non-empty so _finish retains the dict
        # (resolve() mutates it in place after the span has ended)
        self.args[key] = None
        with self.rec._lock:
            self.rec._pending.append((self.args, key, value))
        return self

    @property
    def done(self) -> bool:
        return self.dur is not None


class NullSpan:
    """Shared no-op stand-in returned while obs is disabled: every
    method is a cheap self-return, so instrumentation sites cost one
    dict lookup and an attribute call."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def begin(self):
        return self

    def end(self):
        return None

    def set(self, **attrs):
        return self

    def defer(self, key, value):
        return self

    done = True


NULL_SPAN = NullSpan()


class Recorder:
    """Collects spans/counters/gauges/histograms for one run.

    Host-side only: ``clock`` is a monotonic timer (``perf_counter``),
    events are plain dicts, and the only device interaction is the
    deferred-read list drained by :meth:`resolve` at a barrier.

    Thread-safe: the batcher's worker threads and the main thread mutate
    counters/hists concurrently, so every read-modify-write goes through
    one uncontended lock (the disabled path in :mod:`repro.obs` never
    reaches it).

    Opt-in extras (both default off, both drained at barriers only):

    * ``memory_snapshots`` — each :meth:`resolve` also records backend
      allocator gauges (``backend.mem.d<id>.*``) from
      ``device.memory_stats()``; that call is a device-runtime read, so
      it is allowed *only* lexically inside ``resolve`` (lint rule
      ``obs-deferred-sync``).
    * ``capture_costs`` — :mod:`repro.obs.costs` AOT-compiles each new
      query/update plan once and records ``plan.cost.*`` counters;
      ``_cost_sigs`` tracks which plan signatures were already captured.
    """

    def __init__(self, clock=time.perf_counter, max_samples: int = 8192,
                 keep_events: bool = True, capture_costs: bool = False,
                 memory_snapshots: bool = False):
        self.clock = clock
        self.keep_events = keep_events
        self.max_samples = max_samples
        self.capture_costs = capture_costs
        self.memory_snapshots = memory_snapshots
        self.events: list[dict] = []       # completed spans, in order
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, dict] = {}  # name -> {value, max, n}
        self.hists: dict[str, Hist] = {}
        self._pending: list[tuple[dict | str, str | None, object]] = []
        self._cost_sigs: set[str] = set()  # plan sigs already captured
        self._lock = threading.Lock()
        self.t0 = self.clock()

    # -- spans -------------------------------------------------------------

    def span(self, name: str, cat: str = "", **attrs) -> Span:
        return Span(self, name, cat, attrs)

    def _finish(self, span: Span) -> None:
        if self.keep_events:
            ev = {"name": span.name, "ts": span.t0 - self.t0,
                  "dur": span.dur}
            if span.cat:
                ev["cat"] = span.cat
            if span.args:
                ev["args"] = span.args
            with self._lock:
                self.events.append(ev)

    def add_span(self, name: str, start_s: float, dur_s: float,
                 cat: str = "", **attrs) -> None:
        """Record an externally-timed section on the timeline
        (``start_s`` in this recorder's clock base)."""
        if self.keep_events:
            ev = {"name": name, "ts": start_s - self.t0, "dur": dur_s}
            if cat:
                ev["cat"] = cat
            if attrs:
                ev["args"] = attrs
            with self._lock:
                self.events.append(ev)

    # -- metrics -----------------------------------------------------------

    def count(self, name: str, n: float = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value) -> None:
        with self._lock:
            g = self.gauges.get(name)
            if g is None:
                self.gauges[name] = {"value": value, "max": value, "n": 1}
            else:
                g["value"] = value
                if value > g["max"]:
                    g["max"] = value
                g["n"] += 1

    def observe(self, name: str, value) -> None:
        with self._lock:
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = Hist(self.max_samples)
            h.observe(value)

    def hist(self, name: str) -> Hist | None:
        return self.hists.get(name)

    def drop(self, prefix: str) -> None:
        """Forget histograms under a name prefix (e.g. a latency
        recorder resetting its measured window after warmup)."""
        with self._lock:
            for name in [n for n in self.hists if n.startswith(prefix)]:
                del self.hists[name]

    # -- deferred device reads (resolve at barriers only) ------------------

    def add_deferred(self, name: str, value) -> None:
        """Attach an in-flight device scalar to counter ``name``; it is
        folded in (via one host read) at the next ``resolve()``."""
        with self._lock:
            self._pending.append((name, None, value))

    @property
    def pending(self) -> int:
        """Deferred device reads not yet resolved."""
        return len(self._pending)

    def resolve(self) -> int:
        """THE sync point: drain the deferred list with one blocking
        host read per entry. Call only from an existing barrier
        (``commit()``, report time) — everywhere else obs must stay
        sync-free (lint rule ``obs-deferred-sync``).

        With ``memory_snapshots`` on, also records backend allocator
        gauges here — ``device.memory_stats()`` is a device-runtime
        read, so this is the only place in the package allowed to call
        it (the extended ``obs-deferred-sync`` rule checks that
        lexically)."""
        with self._lock:
            pending, self._pending = self._pending, []
        if self.memory_snapshots:
            import jax  # deferred import: obs stays stdlib-importable
            for dev in jax.local_devices():
                try:
                    stats = dev.memory_stats()
                except Exception:      # backend without allocator stats
                    stats = None
                if not stats:          # CPU devices report None
                    continue
                used = stats.get("bytes_in_use")
                if used is not None:
                    self.gauge(f"backend.mem.d{dev.id}.bytes_in_use",
                               int(used))
                peak = stats.get("peak_bytes_in_use")
                if peak is not None:
                    self.gauge(f"backend.mem.d{dev.id}.peak_bytes",
                               int(peak))
        if not pending:
            return 0
        import jax  # deferred import: obs stays importable stdlib-only
        for target, key, value in pending:
            value = jax.block_until_ready(value)
            now = self.clock() - self.t0
            if isinstance(target, str):           # deferred counter
                self.count(target, float(value))
            else:                                 # span attribute
                try:
                    target[key] = float(value)
                except (TypeError, ValueError):   # non-scalar payload
                    target[key] = True
                target[f"{key}_resolved_s"] = now
        return len(pending)

    # -- reporting ---------------------------------------------------------

    def report(self) -> dict:
        """Resolve pending reads and reduce everything to one json-able
        payload (the shape the exporters and the view CLI consume)."""
        self.resolve()
        return {
            "wall_s": self.clock() - self.t0,
            "counters": dict(sorted(self.counters.items())),
            "gauges": {k: dict(v)
                       for k, v in sorted(self.gauges.items())},
            "hists": {k: v.to_dict()
                      for k, v in sorted(self.hists.items())},
            "spans": self.span_summary(),
        }

    def span_summary(self) -> dict:
        """Per-name span stats (count, total/mean/p50/p95/p99 ms)."""
        by_name: dict[str, Hist] = {}
        for ev in self.events:
            h = by_name.get(ev["name"])
            if h is None:
                h = by_name[ev["name"]] = Hist(self.max_samples)
            h.observe(ev["dur"])
        out = {}
        for name, h in sorted(by_name.items()):
            s = h.summary(scale=1e3)           # ms
            s["total_ms"] = h.total * 1e3
            out[name] = s
        return out
