"""Trace summary CLI: render an exported obs trace as tables.

Loads either exporter format (Chrome trace-event JSON or JSONL — the
format is sniffed, not flagged) and prints per-span latency stats,
counters, gauges and histogram summaries. Exit status 0 iff the file
parses as an obs trace; CI uses that as the "exported trace is
well-formed" check.

``--by-name`` collapses the raw timeline events to one row per span
name (count / total / mean, sorted by total) — the flat event dump of
a long driver trace is unreadable, the aggregation is what you scan
first. ``--top N`` limits both it and the default span table.

Run::

    PYTHONPATH=src python -m repro.obs.view results/serve_trace.json
    PYTHONPATH=src python -m repro.obs.view trace.jsonl --by-name --top 20
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    """Normalize either exporter format to one report dict with keys
    counters/gauges/hists/spans/events (+ wall_s); ``events`` are the
    raw timeline spans as ``{name, cat, dur_ms}``. Raises ValueError
    for anything that is not an obs trace."""
    with open(path) as f:
        text = f.read()
    if not text.strip():
        raise ValueError(f"{path}: empty file")
    try:
        payload = json.loads(text)
    except json.JSONDecodeError:
        return _from_jsonl(path, text)
    if isinstance(payload, dict) and "traceEvents" in payload:
        other = payload.get("otherData", {})
        for key in ("counters", "hists", "spans"):
            if key not in other:
                raise ValueError(
                    f"{path}: chrome trace without obs otherData.{key}")
        # chrome "X" events carry microsecond durations
        other = dict(other)
        other["events"] = [
            {"name": ev["name"], "cat": ev.get("cat", ""),
             "dur_ms": ev.get("dur", 0.0) / 1e3}
            for ev in payload["traceEvents"] if ev.get("ph") == "X"]
        return other
    raise ValueError(f"{path}: not an obs trace (expected a chrome "
                     f"trace-event object or obs JSONL)")


def _from_jsonl(path: str, text: str) -> dict:
    counters: dict[str, float] = {}
    gauges: dict[str, dict] = {}
    hists: dict[str, dict] = {}
    durs: dict[str, list[float]] = {}
    events: list[dict] = []
    meta: dict = {}
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
            kind = rec.pop("type")
        except (json.JSONDecodeError, KeyError) as exc:
            raise ValueError(f"{path}:{i}: bad obs JSONL record "
                             f"({exc})") from None
        if kind == "meta":
            meta = rec
        elif kind == "span":
            durs.setdefault(rec["name"], []).append(rec["dur"])
            events.append({"name": rec["name"],
                           "cat": rec.get("cat", ""),
                           "dur_ms": rec["dur"] * 1e3})
        elif kind == "counter":
            counters[rec["name"]] = rec["value"]
        elif kind == "gauge":
            gauges[rec.pop("name")] = rec
        elif kind == "hist":
            hists[rec.pop("name")] = rec
        else:
            raise ValueError(f"{path}:{i}: unknown record type {kind!r}")
    spans = {}
    for name, ds in sorted(durs.items()):
        ds.sort()
        n = len(ds)
        spans[name] = {
            "count": n, "total_ms": sum(ds) * 1e3,
            "mean": sum(ds) / n * 1e3,
            "p50": ds[n // 2] * 1e3,
            "p95": ds[min(n - 1, int(0.95 * n))] * 1e3,
            "p99": ds[min(n - 1, int(0.99 * n))] * 1e3,
            "min": ds[0] * 1e3, "max": ds[-1] * 1e3,
        }
    return {"wall_s": meta.get("wall_s"), "counters": counters,
            "gauges": gauges, "hists": hists, "spans": spans,
            "events": events}


def by_name(events: list) -> dict:
    """Collapse raw timeline events to per-name totals:
    ``{name: {cat, count, total_ms, mean_ms}}``."""
    agg: dict[str, dict] = {}
    for ev in events:
        a = agg.get(ev["name"])
        if a is None:
            a = agg[ev["name"]] = {"cat": ev.get("cat", ""),
                                   "count": 0, "total_ms": 0.0}
        a["count"] += 1
        a["total_ms"] += ev.get("dur_ms", 0.0)
    for a in agg.values():
        a["mean_ms"] = a["total_ms"] / a["count"]
    return agg


def render_by_name(report: dict, top: int = 0) -> str:
    agg = by_name(report.get("events", []))
    lines = [f"{'span':34s} {'cat':>10s} {'count':>7s} "
             f"{'total_ms':>10s} {'mean_ms':>9s}"]
    items = sorted(agg.items(), key=lambda kv: -kv[1]["total_ms"])
    for name, a in (items[:top] if top else items):
        lines.append(f"{name:34s} {a['cat']:>10s} {a['count']:7d} "
                     f"{a['total_ms']:10.2f} {a['mean_ms']:9.3f}")
    if not agg:
        lines.append("(no timeline events in this trace)")
    return "\n".join(lines)


def render(report: dict, top: int = 0) -> str:
    lines = []
    wall = report.get("wall_s")
    if wall is not None:
        lines.append(f"wall: {wall * 1e3:,.1f} ms")
    spans = report.get("spans", {})
    if spans:
        lines.append("")
        lines.append(f"{'span':34s} {'count':>7s} {'total_ms':>10s} "
                     f"{'p50_ms':>9s} {'p95_ms':>9s} {'p99_ms':>9s}")
        items = sorted(spans.items(),
                       key=lambda kv: -kv[1].get("total_ms", 0.0))
        for name, s in (items[:top] if top else items):
            lines.append(
                f"{name:34s} {s['count']:7d} {s['total_ms']:10.2f} "
                f"{s.get('p50', 0.0):9.3f} {s.get('p95', 0.0):9.3f} "
                f"{s.get('p99', 0.0):9.3f}")
    counters = report.get("counters", {})
    if counters:
        lines.append("")
        lines.append(f"{'counter':44s} {'value':>12s}")
        for name, value in sorted(counters.items()):
            lines.append(f"{name:44s} {value:12g}")
    gauges = report.get("gauges", {})
    if gauges:
        lines.append("")
        lines.append(f"{'gauge':44s} {'last':>8s} {'max':>8s}")
        for name, g in sorted(gauges.items()):
            lines.append(f"{name:44s} {g.get('value', 0):8g} "
                         f"{g.get('max', 0):8g}")
    hists = report.get("hists", {})
    if hists:
        lines.append("")
        lines.append(f"{'histogram':34s} {'count':>7s} {'mean':>10s} "
                     f"{'p50':>10s} {'p99':>10s} {'max':>10s}")
        for name, h in sorted(hists.items()):
            if not h.get("count"):
                continue
            lines.append(
                f"{name:34s} {h['count']:7d} {h.get('mean', 0):10.4g} "
                f"{h.get('p50', 0):10.4g} {h.get('p99', 0):10.4g} "
                f"{h.get('max', 0):10.4g}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="obs trace file (chrome json or jsonl)")
    ap.add_argument("--top", type=int, default=0,
                    help="show only the N spans with the largest total")
    ap.add_argument("--by-name", action="store_true",
                    help="only the per-span-name aggregation "
                    "(count/total/mean) from the raw timeline events")
    args = ap.parse_args(argv)
    try:
        report = load(args.trace)
    except (OSError, ValueError) as exc:
        print(f"repro.obs.view: {exc}", file=sys.stderr)
        return 1
    if args.by_name:
        print(render_by_name(report, top=args.top))
    else:
        print(render(report, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
