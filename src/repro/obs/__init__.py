"""``repro.obs``: host-sync-free tracing, counters and trace export
for the index -> engine -> server stack.

The serving contract (ROADMAP "Serving runtime", "Contracts") forbids
host reads of device values on dispatch paths — which is exactly where
naive instrumentation would put them. This subsystem is the designed
alternative: every helper below is host-side bookkeeping (monotonic
clock reads, dict updates, list appends), device values are *attached*
to spans/counters and read only at :func:`resolve` — called from
existing barriers (``SpatialServer.commit``, report time) — and the
``obs-deferred-sync`` lint rule holds the package to it.

Usage::

    from repro import obs

    rec = obs.Recorder()
    obs.install(rec)                    # or: with obs.recording(rec):
    with obs.span("serve.step", kind="insert") as sp:
        idx = idx.insert(batch)         # async dispatch
        sp.defer("rows", idx.size)      # attach, don't read
    obs.count("steps")
    obs.observe("batch_rows", 512)      # pow2-bucket histogram
    ...
    obs.resolve()                       # at a barrier: one read each
    obs.export_chrome_trace(rec, "trace.json")   # Perfetto-viewable
    # then: python -m repro.obs.view trace.json

Disabled (no recorder installed) every helper is a near-free no-op:
``span()`` returns a shared :data:`NULL_SPAN` and the counter/histogram
helpers return after one dict-slot check, so instrumentation stays in
the hot path unconditionally (overhead asserted in tests/test_obs.py).

Instrumented out of the box (counter/span names are stable API):

====================================  =================================
``engine.plan_request/_miss``         query-plan cache traffic
``engine.trace``                      query-closure (re)traces — equals
                                      ``repro.core.engine.trace_count``
``engine.route.frontier|flat``        kNN impl routing decisions
``engine.escalation_rounds``          pow2 buffer escalations per call
``index.update_plan_miss``            update-closure compiles
``index.grow/compact/build_retry``    capacity-recovery ladder events
``serving.insert|delete`` spans       update dispatch latency
``serving.evict_block`` span          version-window backpressure stall
``serving.replay`` span               deferred-overflow replays
``serving.commit`` span               exposed commit stall
``batcher.queue_depth`` gauge         rows pending at each enqueue
``batcher.coalesce_rows/pad_rows``    flush batch size / pad waste
``batcher.wait_s``                    request queue wait (submit->flush)
``batcher.flush.<reason>``            size|deadline|result|retarget|
                                      explicit
``server.mem.live_bytes``             head-version buffer bytes (gauge)
``server.mem.window_bytes``           retained-window bytes (gauge)
``server.mem.evicted_bytes``          bytes freed by window eviction
``plan.cost.<sig>.flops|bytes``       captured per-plan cost model
                                      (``Recorder(capture_costs=True)``)
``backend.mem.d<id>.bytes_in_use``    allocator stats, resolve()-only
                                      (``memory_snapshots=True``)
====================================  =================================

Phase 2 adds three memory/cost/drift surfaces (ROADMAP "Observability"):
:mod:`repro.obs.memory` (``nbytes``-metadata accounting — sync-free by
construction), :mod:`repro.obs.costs` (AOT compile-cost capture at
plan-miss sites), and ``python -m repro.obs.regress`` (the perf gate
comparing a fresh smoke run against committed baselines).
"""

from __future__ import annotations

import contextlib

from . import costs
from .export import (chrome_trace, jsonl_records, write_chrome_trace,
                     write_jsonl)
from .memory import fmt_bytes, tree_bytes
from .record import NULL_SPAN, Hist, NullSpan, Recorder, Span, pow2_bucket

__all__ = [
    "Recorder", "Span", "NullSpan", "NULL_SPAN", "Hist", "pow2_bucket",
    "install", "uninstall", "recording", "enabled", "recorder",
    "span", "count", "gauge", "observe", "defer", "resolve",
    "chrome_trace", "jsonl_records", "write_chrome_trace", "write_jsonl",
    "costs", "tree_bytes", "fmt_bytes",
]

# single mutable slot so the disabled-path check is one dict lookup
_STATE: dict = {"rec": None}


def install(rec: Recorder) -> Recorder:
    """Make ``rec`` the process-wide sink for the module-level helpers
    (instrumented library code records through these)."""
    _STATE["rec"] = rec
    return rec


def uninstall() -> None:
    _STATE["rec"] = None


def enabled() -> bool:
    return _STATE["rec"] is not None


def recorder() -> Recorder | None:
    """The installed recorder, or None while disabled."""
    return _STATE["rec"]


@contextlib.contextmanager
def recording(rec: Recorder | None = None):
    """Scoped install: enable obs for a block, restoring the previous
    state (including disabled) on exit. Yields the recorder."""
    rec = rec if rec is not None else Recorder()
    prev = _STATE["rec"]
    _STATE["rec"] = rec
    try:
        yield rec
    finally:
        _STATE["rec"] = prev


# -- instrumentation surface (near-free when disabled) ----------------------

def span(name: str, cat: str = "", **attrs):
    rec = _STATE["rec"]
    if rec is None:
        return NULL_SPAN
    return rec.span(name, cat, **attrs)


def count(name: str, n: float = 1) -> None:
    rec = _STATE["rec"]
    if rec is not None:
        rec.count(name, n)


def gauge(name: str, value) -> None:
    rec = _STATE["rec"]
    if rec is not None:
        rec.gauge(name, value)


def observe(name: str, value) -> None:
    rec = _STATE["rec"]
    if rec is not None:
        rec.observe(name, value)


def defer(name: str, value) -> None:
    """Attach an in-flight device scalar to counter ``name``; folded in
    at the next :func:`resolve` (no host read here)."""
    rec = _STATE["rec"]
    if rec is not None:
        rec.add_deferred(name, value)


def resolve() -> int:
    """Drain deferred device reads — call from an existing barrier only
    (``commit()``, report time); returns the number resolved."""
    rec = _STATE["rec"]
    return rec.resolve() if rec is not None else 0
