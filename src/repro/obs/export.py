"""Exporters: JSON-lines and Chrome trace-event format.

Two on-disk shapes, both derived from :meth:`Recorder.report` /
``Recorder.events`` (so exporting resolves deferred device reads — it
is a report barrier):

* **JSONL** (:func:`write_jsonl`): one object per line — a ``meta``
  line, then every span in timeline order, then ``counter`` /
  ``gauge`` / ``hist`` lines. Grep- and pandas-friendly.
* **Chrome trace events** (:func:`write_chrome_trace`): the
  ``{"traceEvents": [...]}`` JSON that chrome://tracing and Perfetto
  (https://ui.perfetto.dev) load directly. Spans become complete
  ("ph": "X") events with microsecond timestamps; counters, gauges and
  histogram summaries ride in ``otherData`` so the summary CLI
  (:mod:`repro.obs.view`) can reconstruct the full report from the
  trace file alone.
"""

from __future__ import annotations

import json
import os

from .record import Recorder

TRACE_VERSION = 1


def _ensure_dir(path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)


def jsonl_records(rec: Recorder) -> list[dict]:
    report = rec.report()                     # resolves deferred reads
    out: list[dict] = [{"type": "meta", "version": TRACE_VERSION,
                        "wall_s": report["wall_s"]}]
    for ev in rec.events:
        out.append({"type": "span", **ev})
    for name, value in report["counters"].items():
        out.append({"type": "counter", "name": name, "value": value})
    for name, g in report["gauges"].items():
        out.append({"type": "gauge", "name": name, **g})
    for name, h in report["hists"].items():
        out.append({"type": "hist", "name": name, **h})
    return out


def write_jsonl(rec: Recorder, path: str) -> str:
    _ensure_dir(path)
    with open(path, "w") as f:
        for record in jsonl_records(rec):
            f.write(json.dumps(record, sort_keys=True) + "\n")
    return path


def chrome_trace(rec: Recorder, pid: int = 1, tid: int = 1) -> dict:
    report = rec.report()                     # resolves deferred reads
    events = []
    for ev in rec.events:
        out = {"name": ev["name"], "ph": "X", "pid": pid, "tid": tid,
               "ts": ev["ts"] * 1e6, "dur": ev["dur"] * 1e6,
               "cat": ev.get("cat", "obs")}
        if "args" in ev:
            out["args"] = ev["args"]
        events.append(out)
    # counters as Chrome counter ("C") samples at end-of-run so the
    # totals are visible on the timeline too
    t_end = report["wall_s"] * 1e6
    for name, value in report["counters"].items():
        events.append({"name": name, "ph": "C", "pid": pid, "ts": t_end,
                       "args": {"value": value}})
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"version": TRACE_VERSION,
                      "wall_s": report["wall_s"],
                      "counters": report["counters"],
                      "gauges": report["gauges"],
                      "hists": report["hists"],
                      "spans": report["spans"]},
    }


def write_chrome_trace(rec: Recorder, path: str) -> str:
    _ensure_dir(path)
    with open(path, "w") as f:
        json.dump(chrome_trace(rec), f, indent=1, sort_keys=True)
    return path
