"""Live-buffer memory accounting from array metadata — no device reads.

Every tree backend is a registered-dataclass pytree of device arrays,
so its resident footprint is the sum of leaf ``nbytes`` — a pure
shape/dtype computation (``prod(shape) * dtype.itemsize``) that never
touches the device or blocks on an in-flight value. That makes these
helpers safe on dispatch paths: the serving contracts
(``host-sync-in-dispatch``, ``obs-deferred-sync``) hold with no new
pragmas.

Consumers:

* ``SpatialIndex.nbytes`` / ``DistributedIndex.nbytes`` wrap
  :func:`tree_bytes` for one index.
* ``SpatialServer`` tracks bytes per retained version and emits
  ``server.mem.live_bytes`` / ``server.mem.window_bytes`` gauges plus
  eviction-delta counters through :mod:`repro.obs` (no-ops while obs is
  disabled).
* The workload driver's per-scenario report gains a memory section
  (steady/peak window bytes, eviction traffic).

Backend allocator truth (``device.memory_stats()``) is deliberately NOT
here: that is a device-runtime call, taken only inside
``Recorder.resolve`` when ``memory_snapshots`` is set — the extended
``obs-deferred-sync`` lint rule bans it anywhere else in this package.
"""

from __future__ import annotations

__all__ = ["tree_bytes", "fmt_bytes"]


def tree_bytes(tree) -> int:
    """Total bytes of all array leaves in ``tree``.

    Metadata arithmetic only: ``jax.Array.nbytes`` comes from the aval
    (shape x itemsize), so this neither reads device memory nor blocks
    on an in-flight computation. Non-array leaves (ints, floats,
    static config) contribute 0.
    """
    # deferred import: repro.obs stays importable without jax installed
    from jax.tree_util import tree_leaves

    total = 0
    for leaf in tree_leaves(tree):
        nb = getattr(leaf, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total


def fmt_bytes(n: float) -> str:
    """Human-readable byte count (binary units, one decimal)."""
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0 or unit == "TiB":
            return f"{n:,.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024.0
    return f"{n:,.1f} TiB"
