"""Compile-cost attribution: per-plan flops/bytes as ``plan.cost.*``
counters, captured once at each plan-miss site.

The engine and the index facade already count plan compiles
(``engine.plan_miss`` / ``index.update_plan_miss``); this module turns
those misses into *attributed* cost. When the installed recorder was
built with ``capture_costs=True``, :func:`capture` AOT-compiles the
jitted closure once per plan signature (``fn.lower(*args).compile()``)
and records:

    plan.cost.<sig>.flops     — while-loop-aware HLO flop count
    plan.cost.<sig>.bytes     — fusion-boundary traffic model
    plan.cost.<sig>.xla_flops — XLA's own cost_analysis() (body-once
                                for loops; kept for cross-checking)
    plan.cost.captured        — number of plans captured

Flop/byte walking reuses :mod:`repro.launch.hlo_analysis` — XLA's
``cost_analysis()`` counts a while body ONCE, which under-reports the
frontier kNN's chunk loop by the trip count; ``analyze_text`` fixes
that, so roofline/attribution views get honest per-plan cost models.

Costs are static per compiled plan, so consumers split observed
device-wait into "expected from cost model" vs measured (driver
``--attributed``) and roofline gets achieved-vs-model per plan without
re-deriving analytic formulas.

Contracts: everything here is host-side compile machinery — no
``device_get`` / ``.item()`` / ``memory_stats`` (the extended
``obs-deferred-sync`` rule bans them outside ``Recorder.resolve``).
Capture is NOT free: the AOT lowering re-traces the closure (one extra
``engine.trace`` per captured plan) and compiles a second executable,
which is why it is opt-in and excluded from overhead-sensitive runs —
the default ``Recorder()`` never captures.

Signatures are shape-keyed like the plan caches (op, query rows, k /
caps, route), NOT backend-keyed: two backends whose views share a
shape share one captured cost entry.
"""

from __future__ import annotations

__all__ = ["capture", "enabled", "plan_costs", "PREFIX"]

PREFIX = "plan.cost."


def _recorder():
    # function-level import: this module loads during package init
    from . import recorder
    return recorder()


def enabled() -> bool:
    """True iff a recorder with ``capture_costs=True`` is installed."""
    rec = _recorder()
    return rec is not None and getattr(rec, "capture_costs", False)


def capture(fn, args, sig: str) -> bool:
    """Record ``plan.cost.<sig>.*`` counters for jitted closure ``fn``
    called with ``args`` — once per signature per recorder.

    Near-free unless a ``capture_costs`` recorder is installed; then
    the first call per ``sig`` pays one AOT lower+compile (equivalent
    to the plan-miss compile already charged at this site). Returns
    True iff a capture happened.
    """
    rec = _recorder()
    if rec is None or not getattr(rec, "capture_costs", False):
        return False
    with rec._lock:
        if sig in rec._cost_sigs:
            return False
        rec._cost_sigs.add(sig)
    try:
        compiled = fn.lower(*args).compile()
    except Exception:                       # pragma: no cover - backend quirk
        rec.count(f"{PREFIX}capture_failed")
        return False
    hlo = _analyze(compiled)
    rec.count(f"{PREFIX}{sig}.flops", hlo.get("flops", 0))
    rec.count(f"{PREFIX}{sig}.bytes", hlo.get("bytes", 0))
    xla_flops = _xla_flops(compiled)
    if xla_flops is not None:
        rec.count(f"{PREFIX}{sig}.xla_flops", xla_flops)
    rec.count(f"{PREFIX}captured")
    return True


def _analyze(compiled) -> dict:
    """While-loop-aware flops/bytes from the compiled module's HLO."""
    from ..launch.hlo_analysis import analyze_text
    try:
        return analyze_text(compiled.as_text())
    except Exception:                       # pragma: no cover - parse drift
        return {}


def _xla_flops(compiled):
    """XLA's own flop estimate (body-once for loops); None if absent."""
    try:
        ca = compiled.cost_analysis()
    except Exception:                       # pragma: no cover - backend quirk
        return None
    if isinstance(ca, (list, tuple)):       # some backends wrap per-device
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return None
    flops = ca.get("flops")
    return float(flops) if flops is not None else None


def plan_costs(counters: dict) -> dict:
    """Group ``plan.cost.<sig>.<metric>`` counters back into
    ``{sig: {metric: value}}`` (report/post-processing helper)."""
    out: dict[str, dict] = {}
    for name, value in counters.items():
        if not name.startswith(PREFIX):
            continue
        rest = name[len(PREFIX):]
        sig, sep, metric = rest.rpartition(".")
        if not sep or metric not in ("flops", "bytes", "xla_flops"):
            continue
        out.setdefault(sig, {})[metric] = value
    return out
