"""Perf-regression gate: re-run the smoke tier, diff against the
committed baseline, exit non-zero on drift.

The repo commits perf baselines (``results/serve_latency.json``, the
fig4/5/10 records, ``results/roofline.json``) but until this gate
nothing ever compared a fresh run against them — a latency regression
would land silently. ``python -m repro.obs.regress`` (installed as
``repro-regress``) closes the loop:

1. **Collect** — run the smoke suites in-process (the serving driver at
   ``--smoke`` scale plus tiny fig4/fig5/fig10 sweeps on one backend),
   flattening each into named metrics tagged ``better=lower|higher``
   and ``kind=time|struct``. ``struct`` metrics (memory bytes, final
   live-point counts, exact range-output sizes) are deterministic
   functions of the seeded workload — they gate *structure* and get a
   tight band even on noisy CI machines; ``time`` metrics get a wide
   one.
2. **Compare** — per metric, ratio-in-the-worse-direction against the
   committed baseline (``results/regress_smoke.json``), with relative
   tolerance bands: generous on CPU CI (``--ci``), tighter locally.
   A metric missing from the current run is itself a regression.
3. **Validate** — the other committed ``results/`` baselines must
   parse and keep their expected shape (a deleted or truncated
   baseline fails the gate even if every number is fine).
4. **Record** — append a trajectory snapshot
   (``results/bench/BENCH_<n>.json``) so perf history accumulates per
   PR; ``--replay`` re-compares a snapshot without re-running suites.

Knobs: ``--update`` rewrites the baseline from the current run;
``--inject-scale X`` degrades every time metric by ``X`` after
collection (the CI self-test replays the gate's own snapshot with
``--inject-scale 2`` and asserts the exit code is non-zero — proof the
gate actually fails); ``--suites`` selects a subset.

Run::

    PYTHONPATH=src python -m repro.obs.regress            # local bands
    PYTHONPATH=src python -m repro.obs.regress --ci       # CI bands
    PYTHONPATH=src python -m repro.obs.regress --update   # new baseline
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
import time

DEFAULT_BASELINE = "results/regress_smoke.json"
SNAPSHOT_DIR = "results/bench"
RESULTS_DIR = "results"

# tolerance bands (relative): a metric fails when it is worse than
# baseline * (1 + tol) in its bad direction. The smoke tier is tiny
# (few steps, few queries) so per-op p50s jitter well past 2x
# run-to-run on a busy box — the time bands gate order-of-magnitude
# drift, the struct band gates exactness
LOCAL_TIME_TOL = 2.0      # local: fail past 3x
CI_TIME_TOL = 4.0         # CI: CPU runners are noisy — fail past 5x
STRUCT_TOL = 0.25         # bytes/counts are deterministic: keep tight

# values below the floor compare as equal — sub-floor jitter must not
# trip a relative band. Time metrics are ms / q/s scale and the floor
# is 2.0: sub-millisecond async dispatch submits (insert/delete p50)
# spike to ~3ms under host load run-to-run, so they gate only once
# they cross band * 2ms — an absolute order-of-magnitude guard, not a
# relative one. Struct metrics floor at 1 unit (empty range outputs).
TIME_FLOOR = 2.0
STRUCT_FLOOR = 1.0


def metric(value, better: str = "lower", kind: str = "time") -> dict:
    return {"value": float(value), "better": better, "kind": kind}


# ---------------------------------------------------------------------------
# suites (each returns {metric_name: metric(...)}; jax imports deferred)
# ---------------------------------------------------------------------------

def _suite_serve(verbose: bool) -> dict:
    """Serving driver at --smoke scale: one backend, every scenario."""
    from ..data import points as gen
    from ..serving import driver
    # 3 measured steps so per-op p50 is a true median — robust to one
    # slow step (a grow/recompile landing inside the measured window)
    cfg = driver.DriverCfg(n=1500, batch=128, steps=3, warmup=2,
                           queries=16, k=5)
    payload = driver.run(kinds=("spac-h",), scenarios=gen.SCENARIOS,
                         cfg=cfg, verbose=verbose)
    out: dict = {}
    for scen, r in payload["results"]["spac-h"].items():
        lat = r["latency_ms"]
        for op in ("insert", "delete", "knn", "range", "commit"):
            if lat.get(op, {}).get("count"):
                out[f"serve.{scen}.{op}_p50_ms"] = \
                    metric(lat[op]["p50_ms"])
        out[f"serve.{scen}.query_per_s"] = \
            metric(r["throughput"]["query_per_s"], "higher")
        mem = r.get("memory", {})
        out[f"serve.{scen}.mem_steady_bytes"] = \
            metric(mem.get("steady_bytes", 0), "lower", "struct")
        out[f"serve.{scen}.mem_peak_window_bytes"] = \
            metric(mem.get("peak_window_bytes", 0), "lower", "struct")
        # losing points is a correctness regression, not noise
        out[f"serve.{scen}.final_size"] = \
            metric(r["final_size"], "higher", "struct")
    return out


def _suite_fig4(verbose: bool) -> dict:
    """kNN q/s (fig4 shape) at smoke scale, auto impl only."""
    from benchmarks import fig4_knn
    nq = 64
    out = fig4_knn.run(n=4000, nq=nq, dist="varden", indexes=["spac-h"],
                       verbose=verbose, impls=("auto",))
    qps = fig4_knn.qps_records(out, nq, impls=("auto",))
    return {f"fig4.spac-h.{key}_qps": metric(v, "higher")
            for key, v in qps["spac-h"]["auto"].items()}


def _suite_fig5(verbose: bool) -> dict:
    """Range-report q/s + exact mean output size (fig5 shape)."""
    from benchmarks import fig5_range
    nq = 32
    out = fig5_range.run(n=4000, nq=nq, dist="uniform",
                         indexes=["spac-h"], verbose=verbose)
    qps = fig5_range.qps_records(out, nq)
    res: dict = {}
    for side, cell in qps["spac-h"].items():
        res[f"fig5.spac-h.{side}_qps"] = metric(cell["qps"], "higher")
        # exact query output on seeded data — deterministic, so any
        # drift is an exactness regression (struct band)
        res[f"fig5.spac-h.{side}_avg_out"] = \
            metric(cell["avg_out"], "higher", "struct")
    return res


def _suite_fig10(verbose: bool) -> dict:
    """Batch-update throughput (fig10 shape) at smoke scale."""
    from benchmarks import fig10_batch
    n = 8000
    out = fig10_batch.run(n=n, dist="uniform", indexes=["spac-h"],
                          verbose=verbose)
    rec = fig10_batch.throughput_records(out, n)
    return {f"fig10.spac-h.{key}_pts_per_s": metric(v, "higher")
            for key, v in rec["spac-h"].items()}


def _suite_dist(verbose: bool) -> dict:
    """Distributed serving smoke on the simulated 8-device mesh.

    Runs the driver in a **subprocess**: the forced host device count
    must be staged before jax initializes, and the other suites have
    long since initialized this process single-device. Gates structure
    only (routing balance + exact final sizes) — mesh-over-one-CPU
    wall times measure the simulation, not the system."""
    import subprocess
    import sys
    import tempfile
    n_shards = 8
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "dist_smoke.json")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in ("src", env.get("PYTHONPATH", "")) if p)
        proc = subprocess.run(
            [sys.executable, "-m", "repro.serving.driver", "--smoke",
             "--mesh", str(n_shards), "--json", path],
            capture_output=True, text=True, env=env)
        if proc.returncode != 0:
            raise RuntimeError(
                f"distributed smoke failed:\n{proc.stdout}{proc.stderr}")
        if verbose:
            sys.stdout.write(proc.stdout)
        with open(path) as f:
            payload = json.load(f)
    out: dict = {}
    for scen, r in payload["results"]["spac-h"].items():
        d = r["distributed"]
        # deterministic functions of the seeded workload: final live
        # count and the per-shard balance of the key-range routing
        out[f"dist.{scen}.final_size"] = \
            metric(r["final_size"], "higher", "struct")
        out[f"dist.{scen}.shard_min_points"] = \
            metric(d["shard_min_points"], "higher", "struct")
        out[f"dist.{scen}.shard_max_points"] = \
            metric(d["shard_max_points"], "lower", "struct")
    return out


SUITES = {"serve": _suite_serve, "fig4": _suite_fig4,
          "fig5": _suite_fig5, "fig10": _suite_fig10,
          "dist": _suite_dist}


def collect(suite_names, verbose: bool = True) -> dict:
    current: dict = {}
    for name in suite_names:
        if verbose:
            print(f"[regress] suite {name}:", flush=True)
        current.update(SUITES[name](verbose))
    return current


# ---------------------------------------------------------------------------
# comparison
# ---------------------------------------------------------------------------

def _worse_ratio(base: float, cur: float, better: str,
                 floor: float) -> float:
    """Ratio in the metric's bad direction (>1 means worse), floored so
    tiny denominators don't explode the band."""
    b, c = max(base, floor), max(cur, floor)
    return c / b if better == "lower" else b / c


def compare(current: dict, baseline: dict, time_tol: float,
            struct_tol: float):
    """Diff two metric maps -> (rows, n_regressed). Rows are
    (name, base, cur, delta_pct, status); missing-in-current counts as
    a regression (the gate guards metric coverage too)."""
    rows, regressed = [], 0
    for name in sorted(set(baseline) | set(current)):
        b, c = baseline.get(name), current.get(name)
        if c is None:
            rows.append((name, b["value"], None, None, "MISSING"))
            regressed += 1
            continue
        if b is None:
            rows.append((name, None, c["value"], None, "new"))
            continue
        struct = c.get("kind", "time") == "struct"
        tol = struct_tol if struct else time_tol
        floor = STRUCT_FLOOR if struct else TIME_FLOOR
        bv, cv = float(b["value"]), float(c["value"])
        worse = _worse_ratio(bv, cv, c.get("better", "lower"), floor)
        delta = 100.0 * (cv - bv) / max(abs(bv), 1e-12)
        if worse > 1.0 + tol:
            status, regressed = "REGRESSED", regressed + 1
        elif worse < 1.0 / (1.0 + tol):
            status = "improved"
        else:
            status = "ok"
        rows.append((name, bv, cv, delta, status))
    return rows, regressed


def render(rows, time_tol: float, struct_tol: float) -> str:
    lines = [f"{'metric':44s} {'baseline':>12s} {'current':>12s} "
             f"{'delta':>8s}  status",
             "-" * 88]
    for name, bv, cv, delta, status in rows:
        b = "-" if bv is None else f"{bv:12,.4g}"
        c = "-" if cv is None else f"{cv:12,.4g}"
        d = "-" if delta is None else f"{delta:+7.1f}%"
        lines.append(f"{name:44s} {b:>12s} {c:>12s} {d:>8s}  {status}")
    lines.append(f"(bands: time ±{time_tol:.0%} relative, "
                 f"struct ±{struct_tol:.0%})")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# committed-baseline structural validation
# ---------------------------------------------------------------------------

def check_baselines(root: str = RESULTS_DIR) -> list:
    """Every committed results/ baseline must parse and keep its shape —
    a deleted/truncated baseline fails the gate even when all current
    numbers pass."""
    specs = {
        "serve_latency.json": lambda d: bool(d["results"]) and all(
            "latency_ms" in r for kind in d["results"].values()
            for r in kind.values()),
        "fig4_knn.json": lambda d: bool(d["qps"]),
        "fig5_range.json": lambda d: bool(d["qps"]),
        "fig10_batch.json": lambda d: bool(d["update_pts_per_s"]),
        # the roofline baseline must carry the fused-frontier tile
        # sweep (PR 9) next to the per-kernel cells, and the serve
        # trace's captured plan costs must include the pallas-frontier
        # route — the perf gate sees the new kernel's metrics, not just
        # the legacy ones
        "roofline.json": lambda d: bool(d["results"]) and "obs" in d
        and "chosen" in d["block_sweep"],
        "serve_trace.json": lambda d: all(
            "knn_p50_ms" in r for r in d["results"].values())
        and any("pallas-frontier" in s
                for r in d["results"].values()
                for s in r["cost_model"].get("plan_costs", {})),
    }
    problems = []
    for name, ok in specs.items():
        path = os.path.join(root, name)
        try:
            with open(path) as f:
                payload = json.load(f)
            if not ok(payload):
                problems.append(f"{path}: expected structure missing")
        except FileNotFoundError:
            problems.append(f"{path}: committed baseline missing")
        except (ValueError, KeyError, TypeError) as exc:
            problems.append(f"{path}: {exc!r}")
    return problems


# ---------------------------------------------------------------------------
# trajectory snapshots
# ---------------------------------------------------------------------------

def next_snapshot_path(directory: str = SNAPSHOT_DIR) -> str:
    ns = [int(m.group(1)) for p in
          glob.glob(os.path.join(directory, "BENCH_*.json"))
          if (m := re.search(r"BENCH_(\d+)\.json$", p))]
    return os.path.join(directory, f"BENCH_{max(ns, default=0) + 1}.json")


def inject(current: dict, scale: float) -> dict:
    """Test hook: degrade every time metric by ``scale`` (latencies
    multiplied, throughputs divided) — the CI self-test proving the
    gate fails when perf actually regresses."""
    out = {}
    for name, c in current.items():
        c = dict(c)
        if c.get("kind", "time") == "time":
            c["value"] = (c["value"] * scale
                          if c.get("better", "lower") == "lower"
                          else c["value"] / scale)
        out[name] = c
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--suites", default=",".join(SUITES),
                    help=f"comma-separated from {sorted(SUITES)}")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    metavar="PATH")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this run and exit 0")
    ap.add_argument("--ci", action="store_true",
                    help=f"CI bands: time tolerance {CI_TIME_TOL:.0%} "
                    "(CPU runners gate structure, not noise)")
    ap.add_argument("--tol", type=float, default=None,
                    help="override the relative time-metric tolerance "
                    f"(default {LOCAL_TIME_TOL} local, {CI_TIME_TOL} "
                    "with --ci)")
    ap.add_argument("--struct-tol", type=float, default=STRUCT_TOL)
    ap.add_argument("--inject-scale", type=float, default=1.0,
                    metavar="X", help="degrade time metrics by X after "
                    "collection (self-test hook; see module docstring)")
    ap.add_argument("--replay", default=None, metavar="SNAPSHOT",
                    help="compare a previous snapshot's metrics instead "
                    "of re-running the suites")
    ap.add_argument("--snapshot", default=None, metavar="PATH",
                    help="trajectory snapshot path (default: "
                    f"{SNAPSHOT_DIR}/BENCH_<next>.json)")
    ap.add_argument("--no-snapshot", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    verbose = not args.quiet
    time_tol = args.tol if args.tol is not None else \
        (CI_TIME_TOL if args.ci else LOCAL_TIME_TOL)

    suite_names = [s for s in args.suites.split(",") if s]
    unknown = set(suite_names) - set(SUITES)
    if unknown:
        print(f"repro.obs.regress: unknown suites {sorted(unknown)}",
              file=sys.stderr)
        return 2

    if args.replay:
        try:
            with open(args.replay) as f:
                current = json.load(f)["metrics"]
        except (OSError, ValueError, KeyError) as exc:
            print(f"repro.obs.regress: cannot replay {args.replay}: "
                  f"{exc!r}", file=sys.stderr)
            return 2
    else:
        current = collect(suite_names, verbose=verbose)
    if args.inject_scale != 1.0:
        current = inject(current, args.inject_scale)

    if args.update:
        os.makedirs(os.path.dirname(args.baseline) or ".", exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump({"created_unix": time.time(),
                       "suites": suite_names, "metrics": current},
                      f, indent=1, sort_keys=True)
        print(f"wrote regress baseline ({len(current)} metrics) -> "
              f"{args.baseline}")
        return 0

    try:
        with open(args.baseline) as f:
            base_payload = json.load(f)
        baseline = base_payload["metrics"]
    except (OSError, ValueError, KeyError) as exc:
        print(f"repro.obs.regress: no usable baseline at "
              f"{args.baseline} ({exc!r}); run with --update first",
              file=sys.stderr)
        return 2

    rows, regressed = compare(current, baseline, time_tol,
                              args.struct_tol)
    problems = check_baselines()
    print(render(rows, time_tol, args.struct_tol))
    for p in problems:
        print(f"BASELINE PROBLEM: {p}")

    if not args.no_snapshot:
        snap = args.snapshot or next_snapshot_path()
        os.makedirs(os.path.dirname(snap) or ".", exist_ok=True)
        with open(snap, "w") as f:
            json.dump({
                "created_unix": time.time(), "suites": suite_names,
                "ci": args.ci, "baseline": args.baseline,
                "metrics": current, "regressed": regressed,
                "baseline_problems": problems,
                "rows": [{"name": n, "baseline": b, "current": c,
                          "delta_pct": d, "status": s}
                         for n, b, c, d, s in rows],
            }, f, indent=1, sort_keys=True)
        print(f"trajectory snapshot -> {snap}")

    failed = regressed + len(problems)
    print(f"perf gate: {'FAIL' if failed else 'PASS'} "
          f"({regressed} regressed metrics, {len(problems)} baseline "
          f"problems, {len(rows)} compared)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
