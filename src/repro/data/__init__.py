from . import points, tokens  # noqa: F401
