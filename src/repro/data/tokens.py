"""Deterministic synthetic token pipeline for the LM substrate.

Every batch is a pure function of (seed, step) — restart-safe: a job resumed
from step k regenerates batch k exactly (no data-loader state to checkpoint).
Per-shard slicing happens *inside* jit via the batch sharding, so hosts never
materialize the global batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("batch", "seq", "vocab"))
def lm_batch(seed, step, batch: int, seq: int, vocab: int):
    """(tokens, labels) for a causal-LM step; labels are tokens shifted."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    toks = jax.random.randint(key, (batch, seq + 1), 0, vocab,
                              dtype=jnp.int32)
    return toks[:, :-1], toks[:, 1:]


@functools.partial(jax.jit, static_argnames=("batch", "seq", "dim"))
def embedding_batch(seed, step, batch: int, seq: int, dim: int):
    """Precomputed frame/patch embeddings for audio/VLM frontend stubs."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.random.normal(key, (batch, seq, dim), dtype=jnp.float32)
