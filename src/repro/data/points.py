"""Synthetic spatial workloads from the paper (Sec. 5.1), in JAX.

* Uniform   — i.i.d. uniform integer coordinates.
* Sweepline — uniform points sorted along dim 0 (skewed *update order*).
* Varden    — random walk with restarts (skewed *point distribution*,
  clustered; after Gan & Tao [27]).

All generators are deterministic in (seed, shard) so a restarted job
replays the exact same stream — required for fault-tolerant training/update
pipelines (DESIGN.md Sec. 5).

On top of the point generators, :func:`make_trace` builds deterministic
mixed update *traces* for the serving runtime
(:mod:`repro.serving.driver`): per-step (delete batch, insert batch)
pairs over a bootstrap set. Scenarios are every ``GENERATORS`` entry
(churn over the stream, the paper's incremental setting) plus two
dynamic-workload shapes from ``TRACES``:

* ``moving-objects`` — kinetic points: each step displaces a rotating
  block of objects (delete the old positions, insert the displaced).
* ``sliding-window`` — a stream window: each step inserts the head
  batch of the stream and deletes the tail batch, holding size steady.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

DEFAULT_HI = 1 << 20  # coordinate range [0, 2^20), 64-bit-free test default


@functools.partial(jax.jit, static_argnames=("n", "dim", "hi"))
def uniform(key, n: int, dim: int = 2, hi: int = DEFAULT_HI):
    return jax.random.randint(key, (n, dim), 0, hi, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("n", "dim", "hi"))
def sweepline(key, n: int, dim: int = 2, hi: int = DEFAULT_HI):
    p = uniform(key, n, dim, hi)
    return p[jnp.argsort(p[:, 0])]


@functools.partial(jax.jit, static_argnames=("n", "dim", "hi", "step",
                                             "restart_p"))
def varden(key, n: int, dim: int = 2, hi: int = DEFAULT_HI, step: int = 50,
           restart_p: float = 0.01):
    """Random walk with restarts — vectorized via one scan over steps."""
    k1, k2, k3 = jax.random.split(key, 3)
    steps = jax.random.randint(k1, (n, dim), -step, step + 1,
                               dtype=jnp.int32)
    restarts = jax.random.uniform(k2, (n,)) < restart_p
    restart_pos = jax.random.randint(k3, (n, dim), 0, hi, dtype=jnp.int32)

    def body(cur, x):
        st, rs, rp = x
        cur = jnp.where(rs, rp, jnp.clip(cur + st, 0, hi - 1))
        return cur, cur

    init = restart_pos[0]
    _, pts = jax.lax.scan(body, init, (steps, restarts, restart_pos))
    return pts


GENERATORS = {"uniform": uniform, "sweepline": sweepline, "varden": varden}


def batches(seed: int, dist: str, n_total: int, batch: int, dim: int = 2,
            hi: int = DEFAULT_HI):
    """Deterministic batch stream for incremental-update workloads.

    For sweepline/varden the *stream itself* carries the skew (the paper
    feeds batches in stream order), so we generate one sequence and slice.
    """
    key = jax.random.PRNGKey(seed)
    pts = GENERATORS[dist](key, n_total, dim, hi)
    for s in range(0, n_total, batch):
        yield pts[s: s + batch]


class TraceStep(NamedTuple):
    """One serving step: apply ``delete`` (may be None), then ``insert``
    (may be None); queries interleave against the pre-step snapshot."""
    delete: jnp.ndarray | None
    insert: jnp.ndarray | None


class Trace(NamedTuple):
    """A deterministic mixed update workload for the serving runtime."""
    bootstrap: jnp.ndarray        # initial index contents
    steps: tuple[TraceStep, ...]  # replayed in order
    max_live: int                 # peak live points (sizes capacity)


def _trace_of(bootstrap, steps) -> Trace:
    live = peak = int(bootstrap.shape[0])
    for s in steps:
        live += (0 if s.insert is None else int(s.insert.shape[0])) \
            - (0 if s.delete is None else int(s.delete.shape[0]))
        peak = max(peak, live)
    return Trace(bootstrap, tuple(steps), peak)


def trace_churn(dist: str, *, seed: int = 0, n: int, batch: int,
                steps: int, dim: int = 2, hi: int = DEFAULT_HI) -> Trace:
    """The paper's incremental setting as a trace: bootstrap ``n``
    points from ``dist``, then per step insert the next stream batch and
    retire a quarter of the *previous* batch (steps apply delete before
    insert, so deleting from the current batch would be a no-op; step 0
    retires from the bootstrap tail). Stream order carries the skew for
    sweepline/varden, as in :func:`batches`."""
    pts = GENERATORS[dist](jax.random.PRNGKey(seed), n + steps * batch,
                           dim, hi)
    prev = pts[max(n - batch, 0): n]
    out = []
    for s in range(steps):
        ins = pts[n + s * batch: n + (s + 1) * batch]
        out.append(TraceStep(delete=prev[: batch // 4], insert=ins))
        prev = ins
    return _trace_of(pts[:n], out)


def trace_moving_objects(*, seed: int = 0, n: int, batch: int,
                         steps: int, dim: int = 2, hi: int = DEFAULT_HI,
                         disp: int = 2000) -> Trace:
    """Kinetic points: ``n`` objects; each step a rotating block of
    ``batch`` objects moves by a random displacement in [-disp, disp] —
    the index sees delete(old positions) + insert(new positions), the
    classic moving-objects update pattern."""
    if batch > n:
        raise ValueError(f"moving-objects needs batch <= n objects, got "
                         f"batch={batch} > n={n}")
    key = jax.random.PRNGKey(seed)
    pos0 = uniform(key, n, dim, hi)
    pos, out = pos0, []
    for s in range(steps):
        sel = (jnp.arange(batch) + s * batch) % n
        old = pos[sel]
        delta = jax.random.randint(jax.random.fold_in(key, s + 1),
                                   (batch, dim), -disp, disp + 1,
                                   dtype=jnp.int32)
        new = jnp.clip(old + delta, 0, hi - 1)
        pos = pos.at[sel].set(new)
        out.append(TraceStep(delete=old, insert=new))
    return _trace_of(pos0, out)


def trace_sliding_window(*, seed: int = 0, n: int, batch: int,
                         steps: int, dim: int = 2, hi: int = DEFAULT_HI,
                         dist: str = "uniform") -> Trace:
    """Stream window: bootstrap the first ``n`` stream points; step
    ``s`` inserts the next ``batch`` at the head and deletes the oldest
    ``batch`` from the tail, so the live set is a constant-size sliding
    window over the stream."""
    if batch > n:
        raise ValueError(f"sliding-window needs batch <= n window "
                         f"points, got batch={batch} > n={n}")
    pts = GENERATORS[dist](jax.random.PRNGKey(seed), n + steps * batch,
                           dim, hi)
    out = [TraceStep(delete=pts[s * batch: (s + 1) * batch],
                     insert=pts[n + s * batch: n + (s + 1) * batch])
           for s in range(steps)]
    return _trace_of(pts[:n], out)


TRACES = {"moving-objects": trace_moving_objects,
          "sliding-window": trace_sliding_window}

# every scenario the workload driver can replay
SCENARIOS = tuple(GENERATORS) + tuple(TRACES)


def make_trace(scenario: str, *, seed: int = 0, n: int, batch: int,
               steps: int, dim: int = 2, hi: int = DEFAULT_HI) -> Trace:
    """Build the named scenario's trace: a ``GENERATORS`` name runs the
    churn (incremental) pattern over that distribution; a ``TRACES``
    name runs its dedicated dynamic-workload shape."""
    if scenario in GENERATORS:
        return trace_churn(scenario, seed=seed, n=n, batch=batch,
                           steps=steps, dim=dim, hi=hi)
    if scenario in TRACES:
        return TRACES[scenario](seed=seed, n=n, batch=batch, steps=steps,
                                dim=dim, hi=hi)
    raise KeyError(f"unknown scenario {scenario!r}; one of {SCENARIOS}")


def query_boxes(key, n: int, dim: int, side: int, hi: int = DEFAULT_HI):
    """Axis-aligned query boxes with ~side extent (range queries)."""
    k1, k2 = jax.random.split(key)
    lo = jax.random.randint(k1, (n, dim), 0, hi - side, dtype=jnp.int32)
    ext = jax.random.randint(k2, (n, dim), side // 2, side + 1,
                             dtype=jnp.int32)
    return lo, lo + ext
