"""Synthetic spatial workloads from the paper (Sec. 5.1), in JAX.

* Uniform   — i.i.d. uniform integer coordinates.
* Sweepline — uniform points sorted along dim 0 (skewed *update order*).
* Varden    — random walk with restarts (skewed *point distribution*,
  clustered; after Gan & Tao [27]).

All generators are deterministic in (seed, shard) so a restarted job
replays the exact same stream — required for fault-tolerant training/update
pipelines (DESIGN.md Sec. 5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

DEFAULT_HI = 1 << 20  # coordinate range [0, 2^20), 64-bit-free test default


@functools.partial(jax.jit, static_argnames=("n", "dim", "hi"))
def uniform(key, n: int, dim: int = 2, hi: int = DEFAULT_HI):
    return jax.random.randint(key, (n, dim), 0, hi, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("n", "dim", "hi"))
def sweepline(key, n: int, dim: int = 2, hi: int = DEFAULT_HI):
    p = uniform(key, n, dim, hi)
    return p[jnp.argsort(p[:, 0])]


@functools.partial(jax.jit, static_argnames=("n", "dim", "hi", "step",
                                             "restart_p"))
def varden(key, n: int, dim: int = 2, hi: int = DEFAULT_HI, step: int = 50,
           restart_p: float = 0.01):
    """Random walk with restarts — vectorized via one scan over steps."""
    k1, k2, k3 = jax.random.split(key, 3)
    steps = jax.random.randint(k1, (n, dim), -step, step + 1,
                               dtype=jnp.int32)
    restarts = jax.random.uniform(k2, (n,)) < restart_p
    restart_pos = jax.random.randint(k3, (n, dim), 0, hi, dtype=jnp.int32)

    def body(cur, x):
        st, rs, rp = x
        cur = jnp.where(rs, rp, jnp.clip(cur + st, 0, hi - 1))
        return cur, cur

    init = restart_pos[0]
    _, pts = jax.lax.scan(body, init, (steps, restarts, restart_pos))
    return pts


GENERATORS = {"uniform": uniform, "sweepline": sweepline, "varden": varden}


def batches(seed: int, dist: str, n_total: int, batch: int, dim: int = 2,
            hi: int = DEFAULT_HI):
    """Deterministic batch stream for incremental-update workloads.

    For sweepline/varden the *stream itself* carries the skew (the paper
    feeds batches in stream order), so we generate one sequence and slice.
    """
    key = jax.random.PRNGKey(seed)
    pts = GENERATORS[dist](key, n_total, dim, hi)
    for s in range(0, n_total, batch):
        yield pts[s: s + batch]


def query_boxes(key, n: int, dim: int, side: int, hi: int = DEFAULT_HI):
    """Axis-aligned query boxes with ~side extent (range queries)."""
    k1, k2 = jax.random.split(key)
    lo = jax.random.randint(k1, (n, dim), 0, hi - side, dtype=jnp.int32)
    ext = jax.random.randint(k2, (n, dim), side // 2, side + 1,
                             dtype=jnp.int32)
    return lo, lo + ext
