"""Batched LM serving engine: prefill + greedy decode over fixed slots.

This is the *language-model* serving path, kept as the reference
implementation of the closure-caching template; spatial-index serving
lives in :mod:`repro.serving` (SpatialServer + MicroBatcher), which is
what the benchmarks and the workload driver use.

The engine owns jit'd prefill/decode_step closures for one (cfg,
batch, max_len) signature — the serving hot path never retraces. A
request batch is (prompts, n_new): prefill primes the cache for all
slots at once, then decode steps run lock-step (the standard batched
decode; slot-level continuous batching would swap finished slots —
noted as future work, the cache layout already permits per-slot reset).

This closure-caching pattern is the template the spatial-index side
reuses: ``repro.core.index._update_closure`` (updates) and the query
closures in ``repro.core.engine`` (the exact-by-default QueryEngine)
key jitted closures on their static signature the same way. The
serving runtime closes the loop: ``repro.serving.MicroBatcher``
coalesces ragged request streams into pow2-padded batches precisely so
they land on those cached signatures, the way LM serving pads ragged
prompts to fixed prefill shapes (slot-level continuous batching over a
version window — ``repro.serving.SpatialServer`` — is the spatial
analogue of the per-slot cache reset noted above).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelCfg


@functools.lru_cache(maxsize=None)
def _closures(cfg: ModelCfg, max_len: int):
    """jit'd (prefill, step) pair for one (cfg, max_len) signature.

    Cached at module level so two engines with the same signature share
    one trace — the same lru_cache-keyed closure-factory pattern as
    ``repro.core.index._update_closure`` and the query-plan closures in
    ``repro.core.engine`` (enforced tree-wide by the ``uncached-jit``
    contract rule). ``ModelCfg`` is a frozen dataclass, hence hashable.
    """
    def prefill(params, tokens):
        return transformer.prefill(params, tokens, cfg, max_len)

    def step(params, cache, tok):
        return transformer.decode_step(params, cache, tok, cfg)

    return jax.jit(prefill), jax.jit(step)


class ServeEngine:
    def __init__(self, cfg: ModelCfg, params, max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill, self._step = _closures(cfg, max_len)

    def generate(self, prompts, n_new: int, greedy: bool = True, key=None):
        """prompts: (B, P) int32. Returns (B, n_new) generated tokens."""
        logits, cache = self._prefill(self.params, prompts)
        out = []
        for i in range(n_new):
            if greedy:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            tok = tok.astype(jnp.int32)
            out.append(tok)
            if i + 1 < n_new:
                logits, cache = self._step(self.params, cache, tok)
        return jnp.concatenate(out, axis=1)
