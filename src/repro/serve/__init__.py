"""LM serving (prefill/decode over fixed slots).

Spatial-index serving — versioned snapshots, micro-batching, the
workload driver — is :mod:`repro.serving`; this package is the LM-side
reference for the shared jit-closure-caching template (see
``repro.serve.engine`` module docs).
"""

from .engine import ServeEngine  # noqa: F401
