from .runtime import (FaultTolerantLoop, HeartbeatMonitor,  # noqa: F401
                      Snapshotter, StragglerTracker)
