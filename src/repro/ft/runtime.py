"""Fault-tolerance runtime: heartbeats, stragglers, snapshot rollback.

These are the *control-plane* pieces of a 1000-node job. The data plane
(collectives) is XLA's; what a framework owns is: detecting that a step
stopped making progress, deciding whether to roll back or re-mesh, and
making either cheap. Everything here is host-side Python and runs the
same on 1 CPU as on 2048 TPU hosts (per-host singleton objects).

  * HeartbeatMonitor — workers stamp a heartbeat each step; the monitor
    flags hosts whose stamp is older than `timeout`. On TPU pods the
    stamps live in a shared store (etcd/GCS); here an injectable clock +
    dict makes the policy unit-testable.
  * StragglerTracker — robust step-time stats (median + MAD); a host
    slower than median + k*MAD for `patience` consecutive steps is a
    straggler. Policy hook returns "warn" | "rebalance" | "evict";
    evict feeds the elastic re-mesh path (ckpt.restore onto the smaller
    mesh — tests/test_ckpt.py::test_elastic_reshard).
  * Snapshotter — in-memory rolling (step, state) snapshots on host RAM:
    rollback for loss spikes / silent data corruption without touching
    disk. Complements ckpt.async_save (disk, for process death).
  * FaultTolerantLoop — composes the three around a train_step callable:
    run() executes steps, triggers periodic async checkpoints, retries a
    step after simulated failures, and rolls back on divergence.
"""

from __future__ import annotations

import collections
import time
from typing import Callable, Optional

import jax

from repro import ckpt


class HeartbeatMonitor:
    def __init__(self, hosts, timeout: float = 60.0, clock=time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.last = {h: clock() for h in hosts}

    def beat(self, host):
        self.last[host] = self.clock()

    def dead_hosts(self):
        now = self.clock()
        return [h for h, t in self.last.items() if now - t > self.timeout]


class StragglerTracker:
    def __init__(self, k: float = 4.0, patience: int = 3, window: int = 64):
        self.k = k
        self.patience = patience
        self.times: dict[object, collections.deque] = {}
        self.strikes: dict[object, int] = {}
        self.window = window

    def record(self, host, step_time: float):
        self.times.setdefault(
            host, collections.deque(maxlen=self.window)).append(step_time)

    def _stats(self):
        all_t = sorted(t for d in self.times.values() for t in d)
        if not all_t:
            return 0.0, 0.0
        med = all_t[len(all_t) // 2]
        mad = sorted(abs(t - med) for t in all_t)[len(all_t) // 2]
        return med, mad

    def stragglers(self):
        med, mad = self._stats()
        out = []
        for host, d in self.times.items():
            if d and d[-1] > med + self.k * max(mad, 1e-9):
                self.strikes[host] = self.strikes.get(host, 0) + 1
                if self.strikes[host] >= self.patience:
                    out.append(host)
            else:
                self.strikes[host] = 0
        return out


class Snapshotter:
    """Rolling in-memory snapshots (host RAM) for cheap rollback."""

    def __init__(self, keep: int = 2):
        self.keep = keep
        self.snaps: collections.deque = collections.deque(maxlen=keep)

    def snap(self, step: int, state):
        host_state = jax.tree.map(lambda x: jax.device_get(x), state)
        self.snaps.append((step, host_state))

    def rollback(self, shardings=None):
        if not self.snaps:
            raise RuntimeError("no snapshot to roll back to")
        step, host_state = self.snaps[-1]
        put = (lambda x, s: jax.device_put(x, s)) if shardings is not None \
            else (lambda x, s: jax.numpy.asarray(x))
        state = (jax.tree.map(put, host_state, shardings)
                 if shardings is not None
                 else jax.tree.map(lambda x: jax.numpy.asarray(x),
                                   host_state))
        return step, state


class FaultTolerantLoop:
    """Drives train_step with checkpoint/restart + rollback policies."""

    def __init__(self, train_step: Callable, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, snap_every: int = 10,
                 max_retries: int = 2, loss_spike: float = 10.0):
        self.train_step = train_step
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.snap_every = snap_every
        self.max_retries = max_retries
        self.loss_spike = loss_spike
        self.snapshotter = Snapshotter()
        self.rollbacks = 0
        self.retries = 0

    def run(self, state, batches, start_step: int = 0,
            fail_hook: Optional[Callable] = None):
        """state = (params, opt_state). batches: iterable of (step, batch).
        fail_hook(step) may raise to simulate a node failure."""
        params, opt = state
        last_loss = None
        for step, batch in batches:
            if step < start_step:
                continue
            if step % self.snap_every == 0:
                self.snapshotter.snap(step, (params, opt))
            for attempt in range(self.max_retries + 1):
                try:
                    if fail_hook is not None:
                        fail_hook(step)
                    params2, opt2, metrics = self.train_step(params, opt,
                                                             batch)
                    break
                except RuntimeError:
                    self.retries += 1
                    if attempt == self.max_retries:
                        raise
            loss = float(metrics["loss"])
            if last_loss is not None and loss > last_loss * self.loss_spike:
                _, (params, opt) = self.snapshotter.rollback()
                self.rollbacks += 1
                continue
            params, opt, last_loss = params2, opt2, loss
            if self.ckpt_dir and step % self.ckpt_every == 0:
                ckpt.async_save({"params": params, "opt": opt},
                                self.ckpt_dir, step)
        ckpt.wait_pending()
        return params, opt
