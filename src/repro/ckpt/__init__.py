from .checkpoint import (async_save, load_manifest, restore, save,  # noqa
                         wait_pending)
