"""Sharded checkpointing with elastic restore.

Layout: <dir>/step_<k>/manifest.json + one .npy per leaf (keyed by the
flattened pytree path). Restore takes *target shardings* — a job may
restart on a different mesh shape (elastic scaling: lose a pod, restore
what remains) and each leaf is device_put with the new sharding; the
resharding is a host-side gather/scatter, no collective needed.

async_save snapshots to host (jax.device_get — the only synchronous
part) and writes files on a daemon thread, so training continues while
bytes hit disk. wait_pending() joins outstanding writers (call before
process exit or before reading the checkpoint back).

Fault-tolerance contract (tested in tests/test_ckpt.py):
  * save is atomic: files land in a tmp dir, rename on completion —
    a job killed mid-save never corrupts the latest checkpoint;
  * restore(step=None) picks the newest *complete* checkpoint;
  * data pipeline is (seed, step)-deterministic, so restore + replay
    reproduces the exact batch stream (no dataloader state on disk).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

_PENDING: list[threading.Thread] = []


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items[key] = leaf
    return items, treedef


def save(tree, directory: str, step: int):
    """Synchronous atomic save."""
    items, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in items.items()}
    _write(host, directory, step)


def async_save(tree, directory: str, step: int):
    """Snapshot to host now; write on a background thread."""
    items, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in items.items()}
    t = threading.Thread(target=_write, args=(host, directory, step),
                         daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    while _PENDING:
        _PENDING.pop().join()


def _write(host: dict, directory: str, step: int):
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {}
    for k, v in host.items():
        fname = k.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), v)
        manifest[k] = {"file": fname, "shape": list(v.shape),
                       "dtype": str(v.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


def load_manifest(directory: str, step: int | None = None):
    """Newest complete checkpoint (or a specific step)."""
    if step is None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(directory)
            if d.startswith("step_") and not d.endswith(".tmp")
            and os.path.exists(os.path.join(directory, d, "manifest.json")))
        if not steps:
            raise FileNotFoundError(f"no complete checkpoint in {directory}")
        step = steps[-1]
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return path, json.load(f)


def restore(template, directory: str, step: int | None = None,
            shardings=None):
    """Restore into the structure of `template` (a pytree of arrays or
    ShapeDtypeStructs). shardings: optional matching pytree of
    jax.sharding.Sharding for elastic placement on the *current* mesh."""
    path, manifest = load_manifest(directory, step)
    items, treedef = _flatten(template)
    shard_items = (_flatten(shardings)[0] if shardings is not None
                   else {k: None for k in items})
    leaves = {}
    for k, tmpl in items.items():
        meta = manifest["leaves"][k]
        arr = np.load(os.path.join(path, meta["file"]))
        assert tuple(arr.shape) == tuple(tmpl.shape), \
            f"{k}: ckpt {arr.shape} vs template {tmpl.shape}"
        sh = shard_items[k]
        leaves[k] = (jax.device_put(arr, sh) if sh is not None
                     else jax.numpy.asarray(arr))
    ordered = [leaves[k] for k in items]
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest["step"]
