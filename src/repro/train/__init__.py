from .step import TrainCfg, make_train_step  # noqa: F401
