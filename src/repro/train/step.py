"""train_step factory: loss -> grads -> (compressed) update.

Distributed-optimization features (all exercised by tests; flags in
TrainCfg):

  * microbatch gradient accumulation — global batch splits into
    n_microbatch slices scanned sequentially; grads accumulate in f32.
    Under pjit the per-microbatch backward's gradient reduce-scatter
    overlaps the next microbatch's forward (XLA latency-hiding scheduler
    on TPU) — the standard compute/comm overlap at scale.
  * int8 gradient compression with error feedback — each gradient leaf
    quantizes to int8 (per-tensor max scale) before the cross-replica
    reduction; the quantization residual is carried in the optimizer
    state and re-added next step (1-bit-Adam-style EF). In a multi-pod
    deployment this cuts the cross-pod (DCN) gradient bytes 4x at
    equal convergence for the tails of training. Off by default.
  * ZeRO-1 — optimizer state sharded over "data" via
    sharding.rules.zero1_specs (a pure spec-tree change).

The same factory drives the real (CPU) examples and the 512-device
dry-run: nothing here depends on mesh size.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelCfg
from repro.optim.adamw import OptCfg, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    n_microbatch: int = 1
    compress_grads: bool = False
    moment_dtype: str = "float32"   # bf16 halves optimizer HBM (398B-class)
    accum_dtype: str = "float32"    # microbatch gradient accumulator
    opt: OptCfg = dataclasses.field(default_factory=OptCfg)


# ---------------------------------------------------- grad compression

def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_with_ef(grads, ef):
    """int8-quantize each leaf, carrying the residual in ef (f32)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, ef)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_ef


# ------------------------------------------------------------ factory

def _model_loss(cfg: ModelCfg):
    if cfg.kind == "encdec":
        def loss(params, batch):
            return encdec.loss_fn(params, batch["prefix"], batch["tokens"],
                                  batch["labels"], cfg)
    elif cfg.frontend is not None:
        def loss(params, batch):
            return transformer.loss_fn(params, batch["tokens"],
                                       batch["labels"], cfg,
                                       prefix_embed=batch["prefix"])
    else:
        def loss(params, batch):
            return transformer.loss_fn(params, batch["tokens"],
                                       batch["labels"], cfg)
    return loss


def init_train_state(key, cfg: ModelCfg, tcfg: TrainCfg):
    init = (encdec.init_params if cfg.kind == "encdec"
            else transformer.init_params)
    params = init(key, cfg)
    from repro.optim.adamw import adamw_init
    opt = adamw_init(params, jnp.dtype(tcfg.moment_dtype))
    if tcfg.compress_grads:
        opt["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return params, opt


def _grad_specs(cfg, params):
    """ZeRO gradient shardings (param TP spec + "data" on the largest
    free dim) against the ambient mesh; None when no mesh is set."""
    from repro.sharding import constraints, rules
    am = constraints._mesh()
    if am is None or "model" not in am.axis_names:
        return None
    pspecs = rules.param_specs(cfg, am)
    return rules.zero1_specs(pspecs, params, am)


def make_train_step(cfg: ModelCfg, tcfg: Optional[TrainCfg] = None):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics). batch: dict of tokens/labels (+prefix).

    Gradients are pinned to the ZeRO spec (data-sharded) the moment
    they exist: GSPMD then lowers each (micro)batch's gradient
    reduction as a reduce-scatter into data-sharded accumulators
    instead of a full all-reduce — half the wire bytes, and the
    optimizer update runs on 1/dp of the elements (ZeRO-2)."""
    tcfg = tcfg or TrainCfg()
    loss_fn = _model_loss(cfg)

    def pin(grads, params):
        specs = _grad_specs(cfg, params)
        if specs is None:
            return grads
        return jax.tree.map(
            jax.lax.with_sharding_constraint, grads, specs)

    def train_step(params, opt_state, batch):
        if tcfg.n_microbatch == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = pin(grads, params)
        else:
            n = tcfg.n_microbatch
            micro = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                batch)

            acc_dt = jnp.dtype(tcfg.accum_dtype)

            def acc(carry, mb):
                tot, g = carry
                li, gi = jax.value_and_grad(loss_fn)(params, mb)
                gi = pin(gi, params)
                # barrier: stops XLA from sinking the f32 accumulation
                # convert into the backward loop — the per-microbatch
                # gradient reduction then runs on bf16 values (half the
                # wire), accumulation stays f32.
                gi = jax.lax.optimization_barrier(gi)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g, gi)
                return (tot + li, g), None

            zeros = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.float32(0), zeros), micro)
            loss = loss / n
            grads = jax.tree.map(lambda g: g / n, grads)

        if tcfg.compress_grads:
            grads, new_ef = compress_with_ef(grads, opt_state["ef"])
            opt_state = {**opt_state, "ef": new_ef}

        ef = opt_state.pop("ef") if "ef" in opt_state else None
        params, opt_core, metrics = adamw_update(
            grads, {k: opt_state[k] for k in ("m", "v", "step")},
            params, tcfg.opt)
        new_state = dict(opt_core)
        if ef is not None:
            new_state["ef"] = ef
        metrics["loss"] = loss
        return params, new_state, metrics

    return train_step


def make_eval_step(cfg: ModelCfg):
    loss_fn = _model_loss(cfg)

    def eval_step(params, batch):
        return loss_fn(params, batch)

    return eval_step
