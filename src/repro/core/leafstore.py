"""Shared leaf-row machinery for array-based spatial indexes.

TPU adaptation of the paper's blocked leaves (Sec. 2.3 / 4): a leaf is a row of
a ``(R, C)`` array with ``C = 2 * phi`` capacity and slack slots, plus a validity
mask. Batch appends are masked scatters into slack slots (the paper's
partial-order relaxation: nothing is sorted on append); deletions are ranked
multiset matches + an intra-row stable compaction. All helpers are shape-static
and jit-compatible; index structures are functional pytrees.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# +inf stand-in that survives arithmetic. A *numpy* scalar, not a jnp
# one: a device constant here would initialize the jax backend at
# import time and lock the topology before repro.configs.platform can
# stage a simulated mesh (the driver's --mesh flag relies on imports
# staying device-free).
BIG = np.float32(3.4e38)


def chunk_rows_from_sorted(n_total: int, phi: int):
    """Row/slot assignment that packs a sorted sequence into rows of ``phi``.

    Returns (row, slot) for positions 0..n_total-1. Callers mask invalid
    positions themselves (e.g. padded tails).
    """
    pos = jnp.arange(n_total, dtype=jnp.int32)
    return pos // phi, pos % phi


def scatter_to_rows(target, row, slot, values, mask):
    """Masked scatter of ``values[i]`` into ``target[row[i], slot[i]]``."""
    row = jnp.where(mask, row, target.shape[0])  # out-of-bounds => dropped
    return target.at[row, slot].set(values, mode="drop")


def segment_bbox(points, row, mask, num_rows: int):
    """Tight per-row bounding boxes via scatter-min/max.

    points: (N, D); row: (N,) int32; mask: (N,) bool.
    Returns (lo, hi): (num_rows, D). Rows with no points get (+BIG, -BIG).
    """
    dim = points.shape[-1]
    dt = points.dtype
    big = _big_for(dt)
    row = jnp.where(mask, row, num_rows)
    lo = jnp.full((num_rows, dim), big, dt).at[row].min(points, mode="drop")
    hi = jnp.full((num_rows, dim), -big, dt).at[row].max(points, mode="drop")
    return lo, hi


def _big_for(dt):
    if jnp.issubdtype(dt, jnp.floating):
        return jnp.asarray(jnp.finfo(dt).max, dt)
    return jnp.asarray(jnp.iinfo(dt).max, dt)


def row_bbox_from_slots(pts, valid):
    """Recompute (lo, hi) over valid slots of rows. pts: (R, C, D)."""
    dt = pts.dtype
    big = _big_for(dt)
    m = valid[..., None]
    lo = jnp.min(jnp.where(m, pts, big), axis=1)
    hi = jnp.max(jnp.where(m, pts, -big), axis=1)
    return lo, hi


def group_occurrence(group_ids):
    """Occurrence index of each element within its run.

    Equal group ids must be contiguous (the batch is sorted by routing key),
    but runs need not be in ascending id order. occ[i] = i - first index of
    the run containing i (computed with a running-max scan over run starts).
    """
    n = group_ids.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    change = jnp.concatenate(
        [jnp.ones((1,), bool), group_ids[1:] != group_ids[:-1]])
    run_first = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(change, idx, 0))
    return idx - run_first


def append_unsorted(pts_rows, valid_rows, count, row_of, new_pts, new_mask,
                    extras_rows=(), new_extras=()):
    """The partial-order relaxation: scatter-append a *sorted-by-row* batch
    into row slack slots without sorting row contents (paper Alg. 4 line 9).

    row_of must be non-decreasing where new_mask is True (callers sort the
    batch by routing key first — paper sorts by SFC code which implies this).
    Points that would exceed capacity must be masked out by the caller
    (overflow path). Returns updated (pts_rows, valid_rows, count, extras...).
    """
    C = pts_rows.shape[1]
    occ = group_occurrence(row_of)
    slot = count[row_of] + occ
    ok = new_mask & (slot < C)
    pts_rows = scatter_to_rows(pts_rows, row_of, slot, new_pts, ok)
    valid_rows = scatter_to_rows(valid_rows, row_of, slot,
                                 jnp.ones(new_pts.shape[0], bool), ok)
    adds = jnp.zeros_like(count).at[jnp.where(ok, row_of, count.shape[0])].add(
        1, mode="drop")
    out_extras = []
    for tgt, val in zip(extras_rows, new_extras):
        out_extras.append(scatter_to_rows(tgt, row_of, slot, val, ok))
    return pts_rows, valid_rows, count + adds, tuple(out_extras)


def batch_rank_among_equals(sorted_pts, row_of, window: int, mask=None):
    """Rank of each batch point among equal (row, coords) batch entries.

    The batch is sorted by routing key, so equal points are contiguous;
    a window of ``window`` preceding entries suffices (a row cannot match
    more than C slots anyway). mask: only count masked-in predecessors
    (multi-round deletion ranks among *still-unmatched* entries).
    """
    n, dim = sorted_pts.shape
    if mask is None:
        mask = jnp.ones(n, bool)
    rank = jnp.zeros(n, jnp.int32)
    for s in range(1, window + 1):
        prev_pts = jnp.roll(sorted_pts, s, axis=0)
        prev_row = jnp.roll(row_of, s)
        prev_ok = jnp.roll(mask, s)
        same = ((jnp.arange(n) >= s) & prev_ok & (prev_row == row_of)
                & jnp.all(prev_pts == sorted_pts, axis=-1))
        rank = rank + same.astype(jnp.int32)
    return rank


def slot_rank_among_equals(pts_rows, valid_rows):
    """For every slot: number of earlier valid slots in the same row holding
    an identical point. pts_rows: (R, C, D) -> (R, C) int32."""
    eq = jnp.all(pts_rows[:, :, None, :] == pts_rows[:, None, :, :], axis=-1)
    C = pts_rows.shape[1]
    earlier = jnp.tril(jnp.ones((C, C), bool), k=-1)[None]
    return jnp.sum(eq & earlier & valid_rows[:, None, :], axis=-1,
                   dtype=jnp.int32)


def ranked_delete(pts_rows, valid_rows, count, row_of, del_pts, del_mask,
                  window: int):
    """Delete a sorted-by-row batch from rows with exact multiset semantics.

    Each batch entry removes at most one matching valid slot; duplicate batch
    entries remove distinct copies (rank matching). Returns updated
    (valid_rows, count, matched_mask).
    """
    R, C, _ = pts_rows.shape
    n = del_pts.shape[0]
    brank = batch_rank_among_equals(del_pts, row_of, window, del_mask)
    srank = slot_rank_among_equals(pts_rows, valid_rows)   # (R, C)
    # per batch point: candidate slots in its row
    rows_p = pts_rows[row_of]            # (n, C, D)
    rows_v = valid_rows[row_of]          # (n, C)
    rows_r = srank[row_of]               # (n, C)
    eq = jnp.all(rows_p == del_pts[:, None, :], axis=-1)
    hit = eq & rows_v & (rows_r == brank[:, None]) & del_mask[:, None]
    matched = jnp.any(hit, axis=-1)
    slot = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    valid_rows = scatter_to_rows(valid_rows, row_of, slot,
                                 jnp.zeros(n, bool), matched)
    dels = jnp.zeros_like(count).at[
        jnp.where(matched, row_of, R)].add(1, mode="drop")
    return valid_rows, count - dels, matched


def compact_rows(valid_rows, *slot_arrays):
    """Stable push-valid-to-front within each row (after deletions), so that
    ``count`` == number of leading valid slots again. Preserves relative order
    (keeps 'sorted' flags truthful). Applies the same permutation to every
    array in slot_arrays (each (R, C, ...))."""
    order = jnp.argsort(~valid_rows, axis=1, stable=True)   # (R, C)
    out = [jnp.take_along_axis(valid_rows, order, axis=1)]
    for arr in slot_arrays:
        idx = order.reshape(order.shape + (1,) * (arr.ndim - 2))
        out.append(jnp.take_along_axis(arr, jnp.broadcast_to(
            idx, order.shape + arr.shape[2:]) if arr.ndim > 2 else order,
            axis=1))
    return tuple(out)


def take_k_where(mask, k: int):
    """Indices of up to k True entries of mask (padded with -1), plus count.

    Deterministic (ascending index order)."""
    n = mask.shape[0]
    # sort key: False -> large, True -> own index (ascending)
    key = jnp.where(mask, jnp.arange(n, dtype=jnp.int32), jnp.int32(n))
    idx = jnp.argsort(key)[:k].astype(jnp.int32)
    good = mask[idx]
    return jnp.where(good, idx, -1), jnp.sum(mask, dtype=jnp.int32)
