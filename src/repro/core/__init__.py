"""Psi-JAX core: the paper's parallel dynamic spatial indexes.

Index API (the recommended entry point)
---------------------------------------

:func:`make_index` builds any registered tree family behind one facade::

    from repro.core import make_index
    idx = make_index("spac-h", points, phi=32)   # or porth/spac-z/kd/zd/...
    idx = idx.insert(batch).delete(stale)        # pure, auto-capacity
    d2, ids = idx.knn(queries, k=10)             # exact, batched
    counts = idx.range_count(lo, hi)             # exact, auto-sized

* **Registry** — ``index.BACKENDS`` maps kind -> :class:`index.Backend`;
  ``register_backend`` adds new families that every benchmark/test loop
  picks up. Registered: ``porth``, ``spac-h``, ``spac-z``, ``spac-m``,
  ``cpam-h``, ``cpam-z``, ``kd``, ``zd``.
* **Capacity policy** — row capacity comes from ``index.capacity_for``
  (pass ``capacity_points=`` to size for the lifetime maximum). Builds and
  inserts that overflow are transparently retried through
  ``grow -> retry -> compact``; callers never see ``overflowed``.
* **Retracing guarantees** — updates run through jit closures cached on
  ``(backend, batch shape, dtype, static params)``; a fixed-shape update
  stream compiles once. ``make_index(..., donate=True)`` additionally
  donates the old tree's buffers on each update (serving hot path).
* **Query engine** — queries are exact by default: the per-index
  :class:`engine.QueryEngine` auto-sizes the range buffers through
  power-of-two buckets (``truncated`` never escapes the engine), caches
  jitted query plans on ``(op, Q-shape, dtype, k/caps, impl)``, and
  routes kNN between the Pallas brute-force kernel and the chunked
  frontier traversal (``impl="auto"``, override per call).
* **Distributed** — ``make_index(kind, pts, mesh=mesh)`` returns a
  :class:`index.DistributedIndex` sharded over the mesh with the same
  surface (spac-family kinds).

Low-level modules (power users / the paper's algorithms):

  * ``porth``   -- P-Orth tree (SFC-free parallel orth-tree, paper Sec. 3)
  * ``spac``    -- SPaC-tree family (parallel R-tree over SFC order, Sec. 4)
  * ``queries`` -- fixed-capacity batched kNN / range kernels
  * ``engine``  -- exact-by-default query planner over those kernels
  * ``sfc``     -- Morton / Hilbert encodings
  * ``baselines`` -- kd-tree, Zd-like, CPAM-like comparison indexes
  * ``distributed`` -- shard_map-sharded index across a device mesh
"""

from . import (baselines, engine, index, leafstore, porth,  # noqa: F401
               queries, sfc, spac)
from .engine import QueryEngine  # noqa: F401
from .index import (BACKENDS, Backend, DistributedIndex,  # noqa: F401
                    SpatialIndex, capacity_for, get_backend, make_index,
                    register_backend)

__all__ = [
    "BACKENDS", "Backend", "DistributedIndex", "QueryEngine",
    "SpatialIndex", "baselines", "capacity_for", "engine", "get_backend",
    "index", "leafstore", "make_index", "porth", "queries",
    "register_backend", "sfc", "spac",
]
