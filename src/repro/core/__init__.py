"""Psi-JAX core: the paper's parallel dynamic spatial indexes.

Public API:
  * ``porth``   -- P-Orth tree (SFC-free parallel orth-tree, paper Sec. 3)
  * ``spac``    -- SPaC-tree family (parallel R-tree over SFC order, Sec. 4)
  * ``queries`` -- shared exact batched kNN / range engine
  * ``sfc``     -- Morton / Hilbert encodings
  * ``baselines`` -- kd-tree, Zd-like, CPAM-like comparison indexes
  * ``distributed`` -- shard_map-sharded index across a device mesh
"""

from . import baselines, leafstore, porth, queries, sfc, spac  # noqa: F401

__all__ = ["baselines", "leafstore", "porth", "queries", "sfc", "spac"]
