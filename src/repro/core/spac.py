"""SPaC-tree: the paper's parallel R-tree family (Sec. 4), TPU-native form.

Structure-of-arrays representation:
  * points live in rows of ``(R, C=2*phi)`` arrays (blocked leaves),
  * a *directory* (rows sorted by ``min_code``) plays the role of the
    join-balanced search tree: routing a point = one ``searchsorted``,
  * per-row bounding boxes give exact query pruning (queries.py engine).

Paper mechanisms kept intact:
  * HybridSort (Alg. 3): SFC codes are computed fused with the sort pass —
    here ``encode + argsort(codes)`` inside one jit region (XLA fuses the
    encode into the sort's key computation); only ⟨code,id⟩ pairs move
    through the sort, points are gathered once at the end.
  * Partial-order relaxation (Alg. 4): batch inserts append *unsorted* into
    leaf slack slots (`append_unsorted`); a leaf's points are only sorted
    when the leaf overflows and must be split (`Expose`, line 34/43).
  * Leaf-wrapping invariant: rows hold between 1 and C=2*phi points; an
    overflowing leaf's contents (old + incoming) are sorted and re-chunked
    into fresh rows of ``phi`` (fill factor 1/2), allocated from a freelist.

Deviation (documented in DESIGN.md §2): rebalancing is a directory argsort
(O(R log R) on a tiny int array) instead of pointer rotations; per-batch point
data movement remains O(m · phi).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import sfc
from .leafstore import (append_unsorted, chunk_rows_from_sorted, compact_rows,
                        group_occurrence, ranked_delete, row_bbox_from_slots,
                        scatter_to_rows, segment_bbox, take_k_where)
from .queries import LeafView

CODE_MAX = np.uint32(0xFFFFFFFF)  # numpy: keep import device-free


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["pts", "codes", "valid", "count", "active", "bbox_lo",
                 "bbox_hi", "min_code", "unsorted", "order", "num_rows",
                 "overflowed"],
    meta_fields=["phi", "curve", "bits", "coord_bits"])
@dataclasses.dataclass(frozen=True)
class SpacTree:
    pts: Any        # (R, C, D) int32 coordinates
    codes: Any      # (R, C) uint32 SFC codes
    valid: Any      # (R, C) bool
    count: Any      # (R,) int32
    active: Any     # (R,) bool
    bbox_lo: Any    # (R, D) int32
    bbox_hi: Any    # (R, D) int32
    min_code: Any   # (R,) uint32 (CODE_MAX when inactive)
    unsorted: Any   # (R,) bool — the partial-order flag
    order: Any      # (R,) int32 row ids sorted by min_code (inactive last)
    num_rows: Any   # () int32
    overflowed: Any  # () bool — capacity exhausted (grow + rebuild needed)
    phi: int = 32
    curve: str = "hilbert"
    bits: int = 16
    coord_bits: int = 30

    @property
    def capacity_rows(self) -> int:
        return self.pts.shape[0]

    @property
    def row_capacity(self) -> int:
        return self.pts.shape[1]

    @property
    def dim(self) -> int:
        return self.pts.shape[2]

    def view(self) -> LeafView:
        return LeafView(self.pts, self.valid, self.active, self.bbox_lo,
                        self.bbox_hi)

    @property
    def size(self):
        return jnp.sum(jnp.where(self.active, self.count, 0))


def _encode(pts, curve: str, bits: int, coord_bits: int):
    """Quantize coordinates to ``bits``/dim and encode. Quantization only
    affects clustering order, never correctness (leaves are unsorted sets and
    queries are bbox-exact)."""
    shift = max(0, coord_bits - bits)
    q = (pts.astype(jnp.uint32) >> shift)
    if curve == "hilbert":
        return sfc.hilbert_encode(q, bits)
    if curve == "morton":
        return sfc.morton_encode(q, bits)
    raise ValueError(f"unknown curve {curve!r}")


def _dir_mincodes(tree: SpacTree):
    mc = jnp.where(tree.active, tree.min_code, CODE_MAX)
    return mc[tree.order]


def _rebuild_order(active, min_code):
    key = jnp.where(active, min_code, CODE_MAX)
    order = jnp.argsort(key).astype(jnp.int32)
    return order, jnp.sum(active, dtype=jnp.int32)


def _route(tree: SpacTree, codes):
    """Directory lookup: row id owning each code."""
    dmc = _dir_mincodes(tree)
    j = jnp.searchsorted(dmc, codes, side="right").astype(jnp.int32) - 1
    j = jnp.clip(j, 0, tree.capacity_rows - 1)
    return tree.order[j]


# ---------------------------------------------------------------------------
# construction (paper Alg. 3)
# ---------------------------------------------------------------------------

def build_impl(points, mask=None, *, phi: int = 32, curve: str = "hilbert",
               bits: int = 16, coord_bits: int = 30,
               capacity_rows: int | None = None) -> SpacTree:
    """BuildSPaCTree: fused encode+sort, then chunk into phi-blocked rows.

    Unjitted spelling — the only legal call inside a shard_map region
    (jax 0.4.x miscompiles a nested jit there; see ROADMAP "Contracts",
    rule jit-in-shard-map). Single-device callers use :data:`build`.
    """
    n, dim = points.shape
    points = points.astype(jnp.int32)
    if mask is None:
        mask = jnp.ones(n, bool)
    if capacity_rows is None:
        capacity_rows = max(2 * ((n + phi - 1) // phi), 8)
    R, C = capacity_rows, 2 * phi

    codes = _encode(points, curve, bits, coord_bits)
    key = jnp.where(mask, codes, CODE_MAX)
    # HybridSort: only (code, id) pairs move through the sort; points are
    # gathered once afterwards.
    perm = jnp.argsort(key, stable=True).astype(jnp.int32)
    s_codes = key[perm]
    s_pts = points[perm]
    s_ok = mask[perm]

    row, slot = chunk_rows_from_sorted(n, phi)
    pts_rows = jnp.zeros((R, C, dim), jnp.int32)
    codes_rows = jnp.zeros((R, C), jnp.uint32)
    valid_rows = jnp.zeros((R, C), bool)
    pts_rows = scatter_to_rows(pts_rows, row, slot, s_pts, s_ok)
    codes_rows = scatter_to_rows(codes_rows, row, slot, s_codes, s_ok)
    valid_rows = scatter_to_rows(valid_rows, row, slot, jnp.ones(n, bool),
                                 s_ok)
    count = jnp.zeros(R, jnp.int32).at[jnp.where(s_ok, row, R)].add(
        1, mode="drop")
    active = count > 0
    bbox_lo, bbox_hi = segment_bbox(s_pts, row, s_ok, R)
    min_code = jnp.full(R, CODE_MAX, jnp.uint32).at[
        jnp.where(s_ok, row, R)].min(s_codes, mode="drop")
    order, num_rows = _rebuild_order(active, min_code)
    return SpacTree(pts=pts_rows, codes=codes_rows, valid=valid_rows,
                    count=count, active=active, bbox_lo=bbox_lo,
                    bbox_hi=bbox_hi, min_code=min_code,
                    unsorted=jnp.zeros(R, bool), order=order,
                    num_rows=num_rows, overflowed=jnp.array(False),
                    phi=phi, curve=curve, bits=bits, coord_bits=coord_bits)


build = jax.jit(build_impl, static_argnames=("phi", "curve", "bits",
                                             "coord_bits", "capacity_rows"))


# ---------------------------------------------------------------------------
# batch insertion (paper Alg. 4)
# ---------------------------------------------------------------------------

def insert_impl(tree: SpacTree, new_pts, new_mask=None, *,
                max_overflow_rows: int = 64,
                sort_rows: bool = False) -> SpacTree:
    """Batch insertion. ``sort_rows=True`` disables the partial-order
    relaxation (the CPAM-like total-order baseline of Fig. 3).

    Unjitted spelling for shard_map regions; use :data:`insert` outside.
    """
    m, dim = new_pts.shape
    new_pts = new_pts.astype(jnp.int32)
    if new_mask is None:
        new_mask = jnp.ones(m, bool)
    R, C = tree.capacity_rows, tree.row_capacity
    phi = tree.phi

    # --- sort the batch by code (HybridSort on the batch) ---
    codes = _encode(new_pts, tree.curve, tree.bits, tree.coord_bits)
    key = jnp.where(new_mask, codes, CODE_MAX)
    perm = jnp.argsort(key, stable=True).astype(jnp.int32)
    s_codes, s_pts, s_ok = key[perm], new_pts[perm], new_mask[perm]

    # --- route to rows (sorted batch => equal rows contiguous) ---
    row_of = jnp.where(s_ok, _route(tree, s_codes), R)  # R => dropped
    adds = jnp.zeros(R, jnp.int32).at[row_of].add(1, mode="drop")
    # overflow decision (inactive target rows — empty tree — can overflow too)
    over = tree.count + adds > C
    goes_over = over[jnp.clip(row_of, 0, R - 1)] & s_ok
    fits = s_ok & ~goes_over

    # --- phase 1: relaxed append into slack slots (no sorting!) ---
    pts_rows, valid_rows, count, (codes_rows,) = append_unsorted(
        tree.pts, tree.valid, tree.count, row_of, s_pts, fits,
        extras_rows=(tree.codes,), new_extras=(s_codes,))
    seg_lo, seg_hi = segment_bbox(s_pts, row_of, fits, R)
    bbox_lo = jnp.minimum(tree.bbox_lo, seg_lo)
    bbox_hi = jnp.maximum(tree.bbox_hi, seg_hi)
    min_code = tree.min_code.at[jnp.where(fits, row_of, R)].min(
        s_codes, mode="drop")
    touched = adds > 0
    unsorted = tree.unsorted | (touched & ~over)

    # --- phase 2: Expose + split overflowing rows ---
    MOR = max_overflow_rows
    orow_ids, n_over = take_k_where(over, MOR)
    ovalid_rows = orow_ids >= 0
    safe_rows = jnp.maximum(orow_ids, 0)
    old_pts = tree.pts[safe_rows].reshape(MOR * C, dim)
    old_codes = tree.codes[safe_rows].reshape(MOR * C)
    old_ok = (tree.valid[safe_rows] & ovalid_rows[:, None]
              & tree.active[safe_rows][:, None]).reshape(MOR * C)
    buf_pts = jnp.concatenate([old_pts, s_pts], axis=0)
    buf_codes = jnp.concatenate([old_codes, s_codes])
    buf_ok = jnp.concatenate([old_ok, goes_over])
    n_buf = buf_pts.shape[0]

    # band id = which overflowing row owns each buffer point. Re-chunking
    # happens *within* each band: a fresh row must never span two source
    # rows' key ranges, or the directory interval invariant breaks (a
    # fresh row would overlap rows between the two sources in code
    # space, and route-based delete/insert would miss points there).
    inv_map = jnp.full((R + 1,), MOR, jnp.int32).at[
        jnp.where(ovalid_rows, safe_rows, R)].set(
        jnp.arange(MOR, dtype=jnp.int32), mode="drop")
    old_band = jnp.repeat(jnp.arange(MOR, dtype=jnp.int32), C)
    new_band = inv_map[jnp.clip(row_of, 0, R)]
    buf_band = jnp.where(buf_ok,
                         jnp.concatenate([old_band, new_band]), MOR)

    # Expose: order is restored *here*, lazily (paper line 34/43).
    # Lexicographic (band, code) sort via two stable argsorts.
    bkey = jnp.where(buf_ok, buf_codes, CODE_MAX)
    p1 = jnp.argsort(bkey, stable=True).astype(jnp.int32)
    p2 = jnp.argsort(buf_band[p1], stable=True).astype(jnp.int32)
    bperm = p1[p2]
    b_codes, b_pts = bkey[bperm], buf_pts[bperm]
    b_ok, b_band = buf_ok[bperm], buf_band[bperm]

    # band-local chunking into rows of phi
    occ = group_occurrence(b_band)
    local_chunk = occ // phi
    nslot = occ % phi
    # dense-rank the (band, chunk) keys -> freelist slots (fk is
    # nondecreasing over the sorted buffer, so a change-flag cumsum
    # ranks them)
    K = C // phi + (m + phi - 1) // phi + 1
    fk = b_band * K + local_chunk
    chg = b_ok & jnp.concatenate(
        [jnp.ones((1,), bool), (fk[1:] != fk[:-1])])
    dense = jnp.cumsum(chg.astype(jnp.int32)) - 1
    nrow_needed = jnp.sum(chg, dtype=jnp.int32)

    NR = MOR * (C // phi) + (m + phi - 1) // phi + MOR
    free_ids, _ = take_k_where(~tree.active & (adds == 0), NR)
    in_new = b_ok & (dense < NR)
    dest_row = jnp.where(in_new, jnp.maximum(free_ids, 0)[
        jnp.clip(dense, 0, NR - 1)], R)
    can_alloc = (nrow_needed <= jnp.sum(free_ids >= 0)) & (n_over <= MOR)
    dest_row = jnp.where(can_alloc, dest_row, R)

    pts_rows = scatter_to_rows(pts_rows, dest_row, nslot, b_pts, in_new)
    codes_rows = scatter_to_rows(codes_rows, dest_row, nslot, b_codes, in_new)
    valid_rows = scatter_to_rows(valid_rows, dest_row, nslot,
                                 jnp.ones(n_buf, bool), in_new)
    ncount = jnp.zeros(R, jnp.int32).at[dest_row].add(1, mode="drop")
    nlo, nhi = segment_bbox(b_pts, jnp.where(in_new, dest_row, R), in_new, R)
    nmin = jnp.full(R, CODE_MAX, jnp.uint32).at[dest_row].min(
        b_codes, mode="drop")

    newly_active = ncount > 0
    count = jnp.where(newly_active, ncount, count)
    bbox_lo = jnp.where(newly_active[:, None], nlo, bbox_lo)
    bbox_hi = jnp.where(newly_active[:, None], nhi, bbox_hi)
    min_code = jnp.where(newly_active, nmin, min_code)
    unsorted = jnp.where(newly_active, False, unsorted)

    # activate appended rows; deactivate + fully reset the split rows
    dropped = over & can_alloc
    active = ((tree.active | (adds > 0)) & ~dropped) | newly_active
    valid_rows = jnp.where(dropped[:, None], False, valid_rows)
    count = jnp.where(dropped, 0, count)
    big = jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)
    bbox_lo = jnp.where(dropped[:, None], big, bbox_lo)
    bbox_hi = jnp.where(dropped[:, None], -big, bbox_hi)
    min_code = jnp.where(dropped, CODE_MAX, min_code)
    unsorted = jnp.where(dropped, False, unsorted)

    if sort_rows:  # CPAM-like total-order baseline: sort every touched row
        order_c = jnp.argsort(jnp.where(valid_rows, codes_rows, CODE_MAX),
                              axis=1, stable=True)
        codes_rows = jnp.take_along_axis(codes_rows, order_c, axis=1)
        valid_rows = jnp.take_along_axis(valid_rows, order_c, axis=1)
        pts_rows = jnp.take_along_axis(
            pts_rows, order_c[..., None].repeat(dim, -1), axis=1)
        unsorted = jnp.zeros_like(unsorted)

    order, num_rows = _rebuild_order(active, min_code)
    new_tree = dataclasses.replace(
        tree, pts=pts_rows, codes=codes_rows, valid=valid_rows, count=count,
        active=active, bbox_lo=bbox_lo, bbox_hi=bbox_hi, min_code=min_code,
        unsorted=unsorted, order=order, num_rows=num_rows)
    ok_all = can_alloc & (n_over <= MOR)
    # all-or-nothing: on capacity shortfall return the tree unchanged with the
    # overflowed flag set (caller compacts to a larger capacity and retries)
    failed = dataclasses.replace(tree, overflowed=jnp.array(True))
    return jax.tree.map(lambda a, b: jnp.where(ok_all, a, b),
                        new_tree, failed)


insert = jax.jit(insert_impl,
                 static_argnames=("max_overflow_rows", "sort_rows"))


# ---------------------------------------------------------------------------
# batch deletion
# ---------------------------------------------------------------------------

def delete_impl(tree: SpacTree, del_pts, del_mask=None) -> SpacTree:
    """Batch deletion: banded route, ranked multiset match, intra-row
    compaction, bbox/min_code refresh for touched rows, directory rebuild.

    Unjitted spelling for shard_map regions — the delete path's
    while_loop is exactly the construct the jax 0.4.x nested-jit
    miscompile corrupts. Use :data:`delete` outside shard_map.

    Banded routing: a code equal to a row's min_code may have copies in
    *preceding* rows too (an equal-code run split across row boundaries
    at build/split time; every interior row of such a band has
    min_code == code exactly). Each entry's candidate band is directory
    positions [searchsorted_left - 1, searchsorted_right - 1]; a
    while_loop walks the band until every remaining entry has exhausted
    its rows — exact for any duplicate load, and the trip count is the
    widest band actually present (1-2 rows for typical data)."""
    m, dim = del_pts.shape
    del_pts = del_pts.astype(jnp.int32)
    if del_mask is None:
        del_mask = jnp.ones(m, bool)
    R, C = tree.capacity_rows, tree.row_capacity

    codes = _encode(del_pts, tree.curve, tree.bits, tree.coord_bits)
    key = jnp.where(del_mask, codes, CODE_MAX)
    perm = jnp.argsort(key, stable=True).astype(jnp.int32)
    s_codes, s_pts, s_ok = key[perm], del_pts[perm], del_mask[perm]

    dm = _dir_mincodes(tree)
    iL = jnp.searchsorted(dm, s_codes, side="left").astype(jnp.int32)
    iR = jnp.searchsorted(dm, s_codes, side="right").astype(jnp.int32)

    def cond(state):
        o, _, _, remaining, _ = state
        return jnp.any(remaining & (iL - 1 + o <= iR - 1))

    def body(state):
        o, valid_rows, count, remaining, touched = state
        pos = jnp.clip(jnp.minimum(iL - 1 + o, iR - 1), 0, R - 1)
        row_of = jnp.where(remaining, tree.order[pos], R - 1)
        valid_rows, count, matched = ranked_delete(
            tree.pts, valid_rows, count, row_of, s_pts, remaining,
            window=C)
        touched = touched.at[jnp.where(matched, row_of, R)].set(
            True, mode="drop")
        return (o + 1, valid_rows, count, remaining & ~matched, touched)

    _, valid_rows, count, _, touched = jax.lax.while_loop(
        cond, body, (jnp.int32(0), tree.valid, tree.count, s_ok,
                     jnp.zeros(R, bool)))
    # intra-row stable compaction keeps `count == leading valid slots`
    cvalid, cpts, ccodes = compact_rows(valid_rows, tree.pts, tree.codes)
    valid_rows = jnp.where(touched[:, None], cvalid, valid_rows)
    pts_rows = jnp.where(touched[:, None, None], cpts, tree.pts)
    codes_rows = jnp.where(touched[:, None], ccodes, tree.codes)

    active = tree.active & (count > 0)
    lo, hi = row_bbox_from_slots(pts_rows, valid_rows & active[:, None])
    bbox_lo = jnp.where(touched[:, None], lo, tree.bbox_lo)
    bbox_hi = jnp.where(touched[:, None], hi, tree.bbox_hi)
    mc = jnp.min(jnp.where(valid_rows & active[:, None], codes_rows,
                           CODE_MAX), axis=1)
    min_code = jnp.where(touched, mc, tree.min_code)
    order, num_rows = _rebuild_order(active, min_code)
    return dataclasses.replace(
        tree, pts=pts_rows, codes=codes_rows, valid=valid_rows, count=count,
        active=active, bbox_lo=bbox_lo, bbox_hi=bbox_hi, min_code=min_code,
        order=order, num_rows=num_rows)


delete = jax.jit(delete_impl)


def grow(tree: SpacTree, capacity_rows: int) -> SpacTree:
    """Pad the row arrays to a larger capacity (outside jit; the production
    check-and-grow pattern between jit steps)."""
    R = tree.capacity_rows
    if capacity_rows <= R:
        return tree
    extra = capacity_rows - R

    def pad(a, fill):
        pw = [(0, extra)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, pw, constant_values=fill)

    big = jnp.iinfo(jnp.int32).max
    arrays = dict(
        pts=pad(tree.pts, 0), codes=pad(tree.codes, 0),
        valid=pad(tree.valid, False), count=pad(tree.count, 0),
        active=pad(tree.active, False), bbox_lo=pad(tree.bbox_lo, big),
        bbox_hi=pad(tree.bbox_hi, -big),
        min_code=pad(tree.min_code, CODE_MAX),
        unsorted=pad(tree.unsorted, False))
    order, num_rows = _rebuild_order(arrays["active"], arrays["min_code"])
    return dataclasses.replace(tree, **arrays, order=order,
                               num_rows=num_rows)


def free_rows(tree: SpacTree) -> int:
    return int(jnp.sum(~tree.active))


def extract_points(tree: SpacTree):
    """All (point, validity) pairs, flattened — for rebuilds/compaction."""
    R, C, dim = tree.pts.shape
    ok = (tree.valid & tree.active[:, None]).reshape(R * C)
    return tree.pts.reshape(R * C, dim), ok


def compact(tree: SpacTree, capacity_rows: int | None = None) -> SpacTree:
    """Full rebuild (bulk rebalance / grow). Not jit — shapes may change."""
    pts, ok = extract_points(tree)
    return build(pts, ok, phi=tree.phi, curve=tree.curve, bits=tree.bits,
                 coord_bits=tree.coord_bits,
                 capacity_rows=capacity_rows or tree.capacity_rows)
