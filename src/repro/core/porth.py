"""P-Orth tree: the paper's SFC-free parallel orth-tree (Sec. 3), TPU-native.

The paper's construction sieves points through a λ-level tree skeleton per
round — conceptually MSD integer sort of Morton codes *without materializing
codes*. The TPU adaptation keeps exactly that structure:

  * per-point sieve state: current cell bounds (lo, hi), accumulated prefix
    key, depth — the bucket of a point is computed by λ·D **coordinate
    comparisons against cell midpoints** (never from an encoded code, so any
    coordinate dtype works: float32 included — the paper's 'Applicability'
    win, Sec. 3);
  * one round = compute buckets for all active points, extend keys, stable
    sort by key (all levels of the tree advance simultaneously — the
    segmented sieve);
  * groups (= cells) with ≤ φ points stop splitting and become leaf rows.

The accumulated prefix keys double as the directory sort keys (they *are*
Morton codes, but they fall out of the comparisons — nothing is encoded,
stored per point, or binary-searched during construction, faithful to the
paper's 'conceptually equivalent to integer sorting SFC codes' claim).

Orth-trees need no rebalancing (paper Sec. 3.2) and are history-independent
modulo leaf wrapping: batch insert routes points to existing leaf cells
(append — orth leaves are naturally unsorted) or creates leaves for empty
regions at the shallowest empty depth; overflowing cells re-run the sieve
seeded at the cell. Deletions remove points and merge fully-leaf sibling
groups whose total fits a leaf (one level per batch, amortized).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .leafstore import (chunk_rows_from_sorted, compact_rows, ranked_delete,
                        row_bbox_from_slots, scatter_to_rows, segment_bbox,
                        take_k_where)
from .queries import LeafView

KEY_MAX = np.uint32(0xFFFFFFFF)  # numpy: keep import device-free


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["pts", "valid", "count", "active", "bbox_lo", "bbox_hi",
                 "cell_lo", "cell_hi", "cell_key", "cell_depth", "order",
                 "num_rows", "overflowed", "root_lo", "root_hi"],
    meta_fields=["phi", "lam", "rounds"])
@dataclasses.dataclass(frozen=True)
class POrthTree:
    pts: Any         # (R, C, D)
    valid: Any       # (R, C) bool
    count: Any       # (R,) int32
    active: Any      # (R,) bool
    bbox_lo: Any     # (R, D) tight point bbox
    bbox_hi: Any     # (R, D)
    cell_lo: Any     # (R, D) orth cell region
    cell_hi: Any     # (R, D)
    cell_key: Any    # (R,) uint32 — lo-corner prefix key at full shift
    cell_depth: Any  # (R,) int32 — levels of splitting applied
    order: Any       # (R,) int32 rows sorted by cell_key
    num_rows: Any    # () int32
    overflowed: Any  # () bool
    root_lo: Any     # (D,)
    root_hi: Any     # (D,)
    phi: int = 32
    lam: int = 3     # paper: 3 levels/round in 2D, 2 in 3D
    rounds: int = 5  # total depth = lam * rounds; lam*rounds*D <= 32

    @property
    def capacity_rows(self) -> int:
        return self.pts.shape[0]

    @property
    def row_capacity(self) -> int:
        return self.pts.shape[1]

    @property
    def dim(self) -> int:
        return self.pts.shape[2]

    @property
    def total_depth(self) -> int:
        return self.lam * self.rounds

    @property
    def key_bits(self) -> int:
        return self.total_depth * self.dim

    def view(self) -> LeafView:
        return LeafView(self.pts, self.valid, self.active, self.bbox_lo,
                        self.bbox_hi)

    @property
    def size(self):
        return jnp.sum(jnp.where(self.active, self.count, 0))


# ---------------------------------------------------------------------------
# sieve machinery
# ---------------------------------------------------------------------------

def _midpoint(lo, hi):
    if jnp.issubdtype(lo.dtype, jnp.floating):
        return lo + (hi - lo) * 0.5
    return lo + (hi - lo) // 2


def _split_lambda_levels(pts, lo, hi, lam: int, dim: int):
    """Compute the λ-level bucket of each point inside its cell by midpoint
    comparisons (the skeleton descent). Returns (bucket (N,) uint32, lo', hi')."""
    bucket = jnp.zeros(pts.shape[0], jnp.uint32)
    for _ in range(lam):
        mid = _midpoint(lo, hi)
        gt = pts >= mid                                   # (N, D)
        b = jnp.zeros(pts.shape[0], jnp.uint32)
        for d in range(dim):
            b = b | (gt[:, d].astype(jnp.uint32) << (dim - 1 - d))
        bucket = (bucket << dim) | b
        lo = jnp.where(gt, mid, lo)
        hi = jnp.where(gt, hi, mid)
    return bucket, lo, hi


def _group_stats(sorted_key, ok):
    """Per-point group stats over contiguous equal-key runs of a sorted array.

    Returns (gid, cnt, pos): group index, number of *valid* points in the
    group, position of the point within its group (counting valid and invalid
    alike — invalids sort to the tail as their own run)."""
    n = sorted_key.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    change = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_key[1:] != sorted_key[:-1]])
    gid = jnp.cumsum(change.astype(jnp.int32)) - 1
    cnt_per_gid = jnp.zeros(n, jnp.int32).at[gid].add(ok.astype(jnp.int32))
    cnt = cnt_per_gid[gid]
    gstart = jax.lax.associative_scan(jnp.maximum, jnp.where(change, idx, 0))
    return gid, cnt, idx - gstart


def _sieve_rounds(pts, ok, lo, hi, key, depth, phi: int, lam: int,
                  rounds: int, total_depth: int, key_bits: int):
    """Run up to ``rounds`` sieve rounds. Points whose group is ≤ φ (or whose
    depth is exhausted) stop. Returns the final sorted per-point state."""
    dim = pts.shape[1]
    n = pts.shape[0]

    def sort_all(sort_key, *arrays):
        perm = jnp.argsort(sort_key, stable=True).astype(jnp.int32)
        return tuple(a[perm] for a in arrays)

    # initial sort so groups (seeded cells) are contiguous
    skey = jnp.where(ok, key, KEY_MAX)
    pts, ok, lo, hi, key, depth, skey = sort_all(
        skey, pts, ok, lo, hi, key, depth, skey)

    for _ in range(rounds):
        _, cnt, _ = _group_stats(skey, ok)
        act = ok & (cnt > phi) & (depth + lam <= total_depth)
        bucket, nlo, nhi = _split_lambda_levels(pts, lo, hi, lam, dim)
        shift = jnp.maximum(key_bits - (depth + lam) * dim, 0).astype(
            jnp.uint32)
        key = jnp.where(act, key | (bucket << shift), key)
        lo = jnp.where(act[:, None], nlo, lo)
        hi = jnp.where(act[:, None], nhi, hi)
        depth = jnp.where(act, depth + lam, depth)
        skey = jnp.where(ok, key, KEY_MAX)
        pts, ok, lo, hi, key, depth, skey = sort_all(
            skey, pts, ok, lo, hi, key, depth, skey)
    return pts, ok, lo, hi, key, depth


def _finalize_rows(tree_arrays, pts, ok, lo, hi, key, depth, phi: int,
                   freelist_ids):
    """Chunk sorted sieve output into leaf rows of φ allocated from
    ``freelist_ids`` (padded with -1). Returns updated row arrays + can_alloc.

    tree_arrays: dict with pts/valid/count/active/bbox_lo/bbox_hi/cell_lo/
    cell_hi/cell_key/cell_depth (each (R, ...))."""
    R, C, dim = tree_arrays["pts"].shape
    n = pts.shape[0]
    NR = freelist_ids.shape[0]

    gid, cnt, pos = _group_stats(jnp.where(ok, key, KEY_MAX), ok)
    rows_per_gid = (cnt + phi - 1) // phi  # per point; constant within group
    # exclusive cumsum of rows_per_group over groups, gathered per point
    change = jnp.concatenate([jnp.ones((1,), bool), gid[1:] != gid[:-1]])
    per_group = jnp.where(change, rows_per_gid, 0)
    offset_incl = jnp.cumsum(per_group)
    group_offset = (offset_incl - per_group)[
        jnp.searchsorted(gid, gid, side="left")]
    local = group_offset.astype(jnp.int32) + pos // phi
    slot = pos % phi
    in_new = ok & (local < NR)
    dest = jnp.where(in_new, jnp.maximum(freelist_ids, 0)[
        jnp.clip(local, 0, NR - 1)], R)
    rows_needed = jnp.max(jnp.where(ok, local + 1, 0), initial=0)
    can_alloc = rows_needed <= jnp.sum(freelist_ids >= 0)
    dest = jnp.where(can_alloc, dest, R)

    a = dict(tree_arrays)
    a["pts"] = scatter_to_rows(a["pts"], dest, slot, pts, in_new)
    a["valid"] = scatter_to_rows(a["valid"], dest, slot,
                                 jnp.ones(n, bool), in_new)
    ncount = jnp.zeros(R, jnp.int32).at[dest].add(1, mode="drop")
    newly = ncount > 0
    a["count"] = jnp.where(newly, ncount, a["count"])
    a["active"] = a["active"] | newly
    nlo, nhi = segment_bbox(pts, jnp.where(in_new, dest, R), in_new, R)
    a["bbox_lo"] = jnp.where(newly[:, None], nlo, a["bbox_lo"])
    a["bbox_hi"] = jnp.where(newly[:, None], nhi, a["bbox_hi"])
    # row leader (first point of each row) carries the cell metadata
    leader = in_new & (slot == 0)
    ldest = jnp.where(leader, dest, R)
    a["cell_lo"] = a["cell_lo"].at[ldest].set(lo, mode="drop")
    a["cell_hi"] = a["cell_hi"].at[ldest].set(hi, mode="drop")
    a["cell_key"] = a["cell_key"].at[ldest].set(key, mode="drop")
    a["cell_depth"] = a["cell_depth"].at[ldest].set(depth, mode="drop")
    return a, can_alloc


def _arrays(tree: POrthTree):
    return dict(pts=tree.pts, valid=tree.valid, count=tree.count,
                active=tree.active, bbox_lo=tree.bbox_lo,
                bbox_hi=tree.bbox_hi, cell_lo=tree.cell_lo,
                cell_hi=tree.cell_hi, cell_key=tree.cell_key,
                cell_depth=tree.cell_depth)


def _rebuild_order(active, cell_key):
    key = jnp.where(active, cell_key, KEY_MAX)
    return jnp.argsort(key).astype(jnp.int32), jnp.sum(
        active, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# construction (paper Alg. 1)
# ---------------------------------------------------------------------------

def _empty_arrays(R: int, C: int, dim: int, dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        big = jnp.asarray(jnp.finfo(dtype).max, dtype)
    else:
        big = jnp.asarray(jnp.iinfo(dtype).max, dtype)
    return dict(
        pts=jnp.zeros((R, C, dim), dtype),
        valid=jnp.zeros((R, C), bool),
        count=jnp.zeros(R, jnp.int32),
        active=jnp.zeros(R, bool),
        bbox_lo=jnp.full((R, dim), big, dtype),
        bbox_hi=jnp.full((R, dim), -big, dtype),
        cell_lo=jnp.zeros((R, dim), dtype),
        cell_hi=jnp.zeros((R, dim), dtype),
        cell_key=jnp.full(R, KEY_MAX, jnp.uint32),
        cell_depth=jnp.zeros(R, jnp.int32),
    )


def build_impl(points, root_lo, root_hi, mask=None, *, phi: int = 32,
               lam: int = 3, rounds: int = 5,
               capacity_rows: int | None = None) -> POrthTree:
    """BuildPOrthTree via the segmented sieve.

    Unjitted spelling — the only legal call inside a shard_map region
    (jax 0.4.x miscompiles a nested jit there; see ROADMAP "Contracts",
    rule jit-in-shard-map). Single-device callers use :data:`build`.
    """
    n, dim = points.shape
    assert lam * rounds * dim <= 31, "key exceeds uint32 (enable x64 path)"
    if mask is None:
        mask = jnp.ones(n, bool)
    if capacity_rows is None:
        # orth cells may hold far fewer than phi points (4/8-ary splits can
        # overshoot), so rows scale with n, not n/phi
        capacity_rows = max(min(2 * n, 8 * ((n + phi - 1) // phi)), 16)
    R, C = capacity_rows, 2 * phi
    total_depth, key_bits = lam * rounds, lam * rounds * dim

    lo = jnp.broadcast_to(root_lo.astype(points.dtype), (n, dim))
    hi = jnp.broadcast_to(root_hi.astype(points.dtype), (n, dim))
    key = jnp.zeros(n, jnp.uint32)
    depth = jnp.zeros(n, jnp.int32)
    s = _sieve_rounds(points, mask, lo, hi, key, depth, phi, lam, rounds,
                      total_depth, key_bits)
    arrays = _empty_arrays(R, C, dim, points.dtype)
    freelist = jnp.arange(R, dtype=jnp.int32)
    arrays, can_alloc = _finalize_rows(arrays, *s, phi, freelist)
    order, num_rows = _rebuild_order(arrays["active"], arrays["cell_key"])
    return POrthTree(**arrays, order=order, num_rows=num_rows,
                     overflowed=~can_alloc,
                     root_lo=root_lo.astype(points.dtype),
                     root_hi=root_hi.astype(points.dtype),
                     phi=phi, lam=lam, rounds=rounds)


build = jax.jit(build_impl, static_argnames=("phi", "lam", "rounds",
                                             "capacity_rows"))


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def point_keys(pts, root_lo, root_hi, *, lam: int, rounds: int):
    """Full-depth prefix key of each point via midpoint comparisons.

    These keys ARE Morton codes over the orth skeleton — they fall out
    of the sieve's comparisons without encoding, so they work for any
    coordinate dtype (float included). Standalone spelling: the
    distributed router calls it before a tree exists on the shard."""
    n, dim = pts.shape
    lo = jnp.broadcast_to(root_lo, (n, dim)).astype(pts.dtype)
    hi = jnp.broadcast_to(root_hi, (n, dim)).astype(pts.dtype)
    key = jnp.zeros(n, jnp.uint32)
    for _ in range(rounds):
        bucket, lo, hi = _split_lambda_levels(pts, lo, hi, lam, dim)
        key = (key << (lam * dim)) | bucket
    return key


def _point_keys(tree: POrthTree, pts):
    return point_keys(pts, tree.root_lo, tree.root_hi, lam=tree.lam,
                      rounds=tree.rounds)


def _route(tree: POrthTree, pkeys, ok):
    """Directory lookup + containment test.

    Returns (row, contained): row id whose cell-key range the point key lands
    in; contained=False when that cell does not actually cover the point
    (the point falls in an empty region)."""
    R = tree.capacity_rows
    dmc = jnp.where(tree.active, tree.cell_key, KEY_MAX)[tree.order]
    j = jnp.clip(jnp.searchsorted(dmc, pkeys, side="right").astype(jnp.int32)
                 - 1, 0, R - 1)
    row = tree.order[j]
    rem = (tree.key_bits
           - tree.cell_depth[row] * tree.dim).astype(jnp.uint32)
    contained = ((pkeys >> rem) == (tree.cell_key[row] >> rem)) \
        & tree.active[row] & ok
    return jnp.where(ok, row, R), contained


def _empty_cell_seed(tree: POrthTree, pts, pkeys, missed):
    """For points in empty regions: shallowest depth d* whose cell contains no
    existing row; returns (key, depth, lo, hi) of that cell per point."""
    n, dim = pts.shape
    sorted_keys = jnp.where(tree.active, tree.cell_key, KEY_MAX)[tree.order]
    num = tree.num_rows
    lo = jnp.broadcast_to(tree.root_lo, (n, dim)).astype(pts.dtype)
    hi = jnp.broadcast_to(tree.root_hi, (n, dim)).astype(pts.dtype)
    best_depth = jnp.full(n, tree.total_depth, jnp.int32)
    best_key = pkeys
    best_lo, best_hi = lo, hi
    found = jnp.zeros(n, bool)
    cur_lo, cur_hi = lo, hi
    for d in range(tree.total_depth + 1):
        rem = jnp.uint32(tree.key_bits - d * dim)
        prefix = (pkeys >> rem) << rem if d > 0 else jnp.zeros_like(pkeys)
        nxt = prefix + (jnp.uint32(1) << rem) if d > 0 else KEY_MAX
        lo_i = jnp.searchsorted(sorted_keys, prefix, side="left")
        hi_i = jnp.searchsorted(sorted_keys,
                                jnp.minimum(nxt, KEY_MAX), side="left")
        hi_i = jnp.where(d == 0, num, hi_i)
        empty = (hi_i - lo_i) == 0 if d > 0 else (num == 0)
        take = empty & ~found & missed
        best_depth = jnp.where(take, d, best_depth)
        best_key = jnp.where(take, prefix, best_key)
        best_lo = jnp.where(take[:, None], cur_lo, best_lo)
        best_hi = jnp.where(take[:, None], cur_hi, best_hi)
        found = found | take
        if d < tree.total_depth:
            # descend one level to track cell bounds
            mid = _midpoint(cur_lo, cur_hi)
            gt = pts >= mid
            cur_lo = jnp.where(gt, mid, cur_lo)
            cur_hi = jnp.where(gt, cur_hi, mid)
    return best_key, best_depth, best_lo, best_hi


# ---------------------------------------------------------------------------
# batch insertion (paper Alg. 2)
# ---------------------------------------------------------------------------

def insert_impl(tree: POrthTree, new_pts, new_mask=None, *,
                max_overflow_rows: int = 64) -> POrthTree:
    """Batch insertion (all-or-nothing; sticky ``overflowed`` on
    capacity shortfall).

    Unjitted spelling for shard_map regions; use :data:`insert` outside.
    """
    m, dim = new_pts.shape
    new_pts = new_pts.astype(tree.pts.dtype)
    if new_mask is None:
        new_mask = jnp.ones(m, bool)
    R, C, phi = tree.capacity_rows, tree.row_capacity, tree.phi

    pkeys = _point_keys(tree, new_pts)
    skey = jnp.where(new_mask, pkeys, KEY_MAX)
    perm = jnp.argsort(skey, stable=True).astype(jnp.int32)
    s_keys, s_pts, s_ok = skey[perm], new_pts[perm], new_mask[perm]

    row_of, contained = _route(tree, s_keys, s_ok)
    missed = s_ok & ~contained
    row_app = jnp.where(contained, row_of, R)
    adds = jnp.zeros(R, jnp.int32).at[row_app].add(1, mode="drop")
    over = tree.count + adds > C
    goes_over = over[jnp.clip(row_app, 0, R - 1)] & contained
    fits = contained & ~goes_over

    # phase 1: append into leaf cells (orth leaves are naturally unsorted)
    from .leafstore import append_unsorted
    pts_rows, valid_rows, count, _ = append_unsorted(
        tree.pts, tree.valid, tree.count, row_app, s_pts, fits)
    seg_lo, seg_hi = segment_bbox(s_pts, row_app, fits, R)
    bbox_lo = jnp.minimum(tree.bbox_lo, seg_lo)
    bbox_hi = jnp.maximum(tree.bbox_hi, seg_hi)

    # phase 2: rebuild buffer = overflowing cells' contents + their incoming
    # + points in empty regions, sieved from their seed cells.
    MOR = max_overflow_rows
    orow_ids, n_over = take_k_where(over & tree.active, MOR)
    ovalid = orow_ids >= 0
    safe = jnp.maximum(orow_ids, 0)
    old_pts = tree.pts[safe].reshape(MOR * C, dim)
    old_ok = (tree.valid[safe] & ovalid[:, None]).reshape(MOR * C)
    old_lo = jnp.repeat(tree.cell_lo[safe], C, axis=0)
    old_hi = jnp.repeat(tree.cell_hi[safe], C, axis=0)
    old_key = jnp.repeat(tree.cell_key[safe], C)
    old_depth = jnp.repeat(tree.cell_depth[safe], C)

    seed_key, seed_depth, seed_lo, seed_hi = _empty_cell_seed(
        tree, s_pts, s_keys, missed)
    # incoming points for overflowing rows seed at that row's cell
    inc_over = goes_over
    rcl = tree.cell_lo[jnp.clip(row_app, 0, R - 1)]
    rch = tree.cell_hi[jnp.clip(row_app, 0, R - 1)]
    rck = tree.cell_key[jnp.clip(row_app, 0, R - 1)]
    rcd = tree.cell_depth[jnp.clip(row_app, 0, R - 1)]
    root_lo = jnp.broadcast_to(tree.root_lo, (m, dim)).astype(s_pts.dtype)
    root_hi = jnp.broadcast_to(tree.root_hi, (m, dim)).astype(s_pts.dtype)
    new_in = missed | goes_over
    b2_lo = jnp.where(inc_over[:, None], rcl,
                      jnp.where(missed[:, None], seed_lo, root_lo))
    b2_hi = jnp.where(inc_over[:, None], rch,
                      jnp.where(missed[:, None], seed_hi, root_hi))
    b2_key = jnp.where(inc_over, rck, jnp.where(missed, seed_key, 0))
    b2_depth = jnp.where(inc_over, rcd, jnp.where(missed, seed_depth, 0))

    buf_pts = jnp.concatenate([old_pts, s_pts], axis=0)
    buf_ok = jnp.concatenate([old_ok, new_in])
    buf_lo = jnp.concatenate([old_lo, b2_lo], axis=0)
    buf_hi = jnp.concatenate([old_hi, b2_hi], axis=0)
    buf_key = jnp.concatenate([old_key, b2_key])
    buf_depth = jnp.concatenate([old_depth, b2_depth])

    s = _sieve_rounds(buf_pts, buf_ok, buf_lo, buf_hi, buf_key, buf_depth,
                      phi, tree.lam, tree.rounds, tree.total_depth,
                      tree.key_bits)

    dropped = over & tree.active & ovalid_mask(orow_ids, R)
    arrays = dict(pts=pts_rows, valid=valid_rows, count=count,
                  active=tree.active | (adds > 0),
                  bbox_lo=bbox_lo, bbox_hi=bbox_hi,
                  cell_lo=tree.cell_lo, cell_hi=tree.cell_hi,
                  cell_key=tree.cell_key, cell_depth=tree.cell_depth)
    # reset rows being rebuilt before re-filling
    arrays = _reset_rows(arrays, dropped)
    NR = MOR * (C // phi) + m + 2
    freelist, _ = take_k_where(~arrays["active"], NR)
    arrays, can_alloc = _finalize_rows(arrays, *s, phi, freelist)
    order, num_rows = _rebuild_order(arrays["active"], arrays["cell_key"])
    ok_all = can_alloc & (n_over <= MOR)
    new_tree = dataclasses.replace(
        tree, **arrays, order=order, num_rows=num_rows,
        overflowed=tree.overflowed)
    # all-or-nothing: on capacity shortfall return the tree unchanged with the
    # overflowed flag set (caller compacts to a larger capacity and retries)
    failed = dataclasses.replace(tree, overflowed=jnp.array(True))
    return jax.tree.map(lambda a, b: jnp.where(ok_all, a, b),
                        new_tree, failed)


insert = jax.jit(insert_impl, static_argnames=("max_overflow_rows",))


def ovalid_mask(orow_ids, R: int):
    m = jnp.zeros(R + 1, bool).at[
        jnp.where(orow_ids >= 0, orow_ids, R)].set(True)
    return m[:R]


def _reset_rows(arrays, mask):
    a = dict(arrays)
    dt = a["pts"].dtype
    big = (jnp.asarray(jnp.finfo(dt).max, dt)
           if jnp.issubdtype(dt, jnp.floating)
           else jnp.asarray(jnp.iinfo(dt).max, dt))
    a["valid"] = jnp.where(mask[:, None], False, a["valid"])
    a["count"] = jnp.where(mask, 0, a["count"])
    a["active"] = a["active"] & ~mask
    a["bbox_lo"] = jnp.where(mask[:, None], big, a["bbox_lo"])
    a["bbox_hi"] = jnp.where(mask[:, None], -big, a["bbox_hi"])
    a["cell_key"] = jnp.where(mask, KEY_MAX, a["cell_key"])
    a["cell_depth"] = jnp.where(mask, 0, a["cell_depth"])
    return a


# ---------------------------------------------------------------------------
# batch deletion
# ---------------------------------------------------------------------------

def delete_impl(tree: POrthTree, del_pts, del_mask=None) -> POrthTree:
    """Batch deletion + one merge pass.

    Unjitted spelling for shard_map regions — this matters doubly here:
    the while_loop below under a nested jit is the documented jax 0.4.x
    shard_map miscompile, and the trailing merge pass must also run as
    its ``_impl`` (a jitted ``merge_pass`` call nested inside the shard
    region would reintroduce exactly that bug *without* tripping the
    lexical jit-in-shard-map lint). Use :data:`delete` outside."""
    m, dim = del_pts.shape
    del_pts = del_pts.astype(tree.pts.dtype)
    if del_mask is None:
        del_mask = jnp.ones(m, bool)
    R, C = tree.capacity_rows, tree.row_capacity

    pkeys = _point_keys(tree, del_pts)
    skey = jnp.where(del_mask, pkeys, KEY_MAX)
    perm = jnp.argsort(skey, stable=True).astype(jnp.int32)
    s_keys, s_pts, s_ok = skey[perm], del_pts[perm], del_mask[perm]
    row_of, contained = _route(tree, s_keys, s_ok)

    # banded deletion: a cell saturated by > C duplicates spans several
    # rows with an IDENTICAL cell_key (orth cells cannot split equal
    # points); walk every row of the target cell's band (usually 1).
    ck_t = tree.cell_key[jnp.clip(row_of, 0, R - 1)]
    dmc = jnp.where(tree.active, tree.cell_key, KEY_MAX)[tree.order]
    iL = jnp.searchsorted(dmc, ck_t, side="left").astype(jnp.int32)
    iR = jnp.searchsorted(dmc, ck_t, side="right").astype(jnp.int32)

    def cond(state):
        o, _, _, remaining, _ = state
        return jnp.any(remaining & (iL + o <= iR - 1))

    def body(state):
        o, valid_rows, count, remaining, touched = state
        pos = jnp.clip(jnp.minimum(iL + o, iR - 1), 0, R - 1)
        rows = jnp.where(remaining, tree.order[pos], R - 1)
        valid_rows, count, matched = ranked_delete(
            tree.pts, valid_rows, count, rows, s_pts, remaining, window=C)
        touched = touched.at[jnp.where(matched, rows, R)].set(
            True, mode="drop")
        return (o + 1, valid_rows, count, remaining & ~matched, touched)

    _, valid_rows, count, _, touched = jax.lax.while_loop(
        cond, body, (jnp.int32(0), tree.valid, tree.count, contained,
                     jnp.zeros(R, bool)))
    cvalid, cpts = compact_rows(valid_rows, tree.pts)
    valid_rows = jnp.where(touched[:, None], cvalid, valid_rows)
    pts_rows = jnp.where(touched[:, None, None], cpts, tree.pts)

    active = tree.active & (count > 0)
    lo, hi = row_bbox_from_slots(pts_rows, valid_rows & active[:, None])
    bbox_lo = jnp.where(touched[:, None], lo, tree.bbox_lo)
    bbox_hi = jnp.where(touched[:, None], hi, tree.bbox_hi)
    arrays = dict(pts=pts_rows, valid=valid_rows, count=count, active=active,
                  bbox_lo=bbox_lo, bbox_hi=bbox_hi, cell_lo=tree.cell_lo,
                  cell_hi=tree.cell_hi,
                  cell_key=jnp.where(active, tree.cell_key, KEY_MAX),
                  cell_depth=jnp.where(active, tree.cell_depth, 0))
    order, num_rows = _rebuild_order(arrays["active"], arrays["cell_key"])
    out = dataclasses.replace(tree, **arrays, order=order, num_rows=num_rows)
    return merge_pass_impl(out)


delete = jax.jit(delete_impl)


def merge_pass_impl(tree: POrthTree) -> POrthTree:
    """One level of the paper's post-deletion flattening: sibling groups that
    are all leaves and whose total fits a leaf merge into their parent cell.

    Unjitted spelling (called from ``delete_impl``, which must stay
    jit-free end to end for shard_map); use :data:`merge_pass` outside."""
    R, C, dim = tree.pts.shape
    rem = jnp.clip(tree.key_bits - (tree.cell_depth - 1) * tree.dim,
                   0, 31).astype(jnp.uint32)
    parent_key = jnp.where(tree.cell_depth > 0,
                           (tree.cell_key >> rem) << rem, KEY_MAX)
    parent_key = jnp.where(tree.active, parent_key, KEY_MAX)
    # group rows by (parent_key, depth) via sort
    okey = parent_key
    order = jnp.argsort(okey).astype(jnp.int32)
    skey = okey[order]
    sdepth = tree.cell_depth[order]
    scount = jnp.where(tree.active, tree.count, 0)[order]
    same = jnp.concatenate([jnp.ones((1,), bool),
                            (skey[1:] != skey[:-1])
                            | (sdepth[1:] != sdepth[:-1])])
    gid = jnp.cumsum(same.astype(jnp.int32)) - 1
    gcount = jnp.zeros(R, jnp.int32).at[gid].add(scount)
    gsize = jnp.zeros(R, jnp.int32).at[gid].add(
        tree.active[order].astype(jnp.int32))
    # rows inside the parent's key range (any depth) — must equal group size
    sorted_keys = jnp.where(tree.active, tree.cell_key, KEY_MAX)[tree.order]
    rem_s = jnp.clip(tree.key_bits - (sdepth - 1) * tree.dim,
                     0, 31).astype(jnp.uint32)
    nxt = skey + (jnp.uint32(1) << rem_s)
    lo_i = jnp.searchsorted(sorted_keys, skey, side="left")
    hi_i = jnp.searchsorted(sorted_keys, nxt, side="left")
    hi_i = jnp.where(nxt < skey, tree.num_rows, hi_i)  # wrap => till end
    in_range = (hi_i - lo_i).astype(jnp.int32)
    mergeable = ((gcount[gid] <= tree.phi) & (gsize[gid] > 1)
                 & (in_range == gsize[gid]) & (skey != KEY_MAX)
                 & (sdepth > 0))
    merge_row = jnp.zeros(R, bool).at[
        jnp.where(mergeable, order, R)].set(True, mode="drop")

    # buffer: all points of merging rows, seeded at their *parent* cell.
    # parents with <= phi points stop immediately in finalize (single row).
    MOR = min(64, R)
    mrow_ids, n_m = take_k_where(merge_row, MOR)
    mvalid = mrow_ids >= 0
    safe = jnp.maximum(mrow_ids, 0)
    b_pts = tree.pts[safe].reshape(MOR * C, dim)
    b_ok = (tree.valid[safe] & mvalid[:, None]).reshape(MOR * C)
    # parent cell bounds: halve upward is not tracked; recompute by descent
    pk = jnp.repeat(parent_key[safe], C)
    pd = jnp.repeat(tree.cell_depth[safe] - 1, C)
    p_lo, p_hi = _cell_bounds_at_depth(tree, b_pts, pd)
    proceed = (n_m <= MOR) & (n_m > 0)
    b_ok = b_ok & proceed

    arrays = _reset_rows(_arrays(tree), merge_row & proceed)
    freelist, _ = take_k_where(~arrays["active"], MOR)
    arrays, can_alloc = _finalize_rows(
        arrays, b_pts, b_ok, p_lo, p_hi, pk, pd, tree.phi, freelist)
    order2, num_rows = _rebuild_order(arrays["active"], arrays["cell_key"])
    new_tree = dataclasses.replace(tree, **arrays, order=order2,
                                   num_rows=num_rows)
    ok_all = can_alloc | ~proceed
    return jax.tree.map(lambda a, b: jnp.where(ok_all, a, b), new_tree, tree)


merge_pass = jax.jit(merge_pass_impl)


def _cell_bounds_at_depth(tree: POrthTree, pts, target_depth):
    """Cell bounds containing each point at the given per-point depth."""
    n, dim = pts.shape
    lo = jnp.broadcast_to(tree.root_lo, (n, dim)).astype(pts.dtype)
    hi = jnp.broadcast_to(tree.root_hi, (n, dim)).astype(pts.dtype)
    out_lo, out_hi = lo, hi
    for d in range(tree.total_depth):
        take = target_depth == d
        out_lo = jnp.where(take[:, None], lo, out_lo)
        out_hi = jnp.where(take[:, None], hi, out_hi)
        mid = _midpoint(lo, hi)
        gt = pts >= mid
        lo = jnp.where(gt, mid, lo)
        hi = jnp.where(gt, hi, mid)
    take = target_depth >= tree.total_depth
    out_lo = jnp.where(take[:, None], lo, out_lo)
    out_hi = jnp.where(take[:, None], hi, out_hi)
    return out_lo, out_hi


def grow(tree: POrthTree, capacity_rows: int) -> POrthTree:
    """Pad the row arrays to a larger capacity (outside jit; the production
    check-and-grow pattern between jit steps)."""
    R = tree.capacity_rows
    if capacity_rows <= R:
        return tree
    extra = capacity_rows - R

    def pad(a, fill):
        pw = [(0, extra)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, pw, constant_values=fill)

    dt = tree.pts.dtype
    big = (jnp.finfo(dt).max if jnp.issubdtype(dt, jnp.floating)
           else jnp.iinfo(dt).max)
    arrays = dict(
        pts=pad(tree.pts, 0), valid=pad(tree.valid, False),
        count=pad(tree.count, 0), active=pad(tree.active, False),
        bbox_lo=pad(tree.bbox_lo, big), bbox_hi=pad(tree.bbox_hi, -big),
        cell_lo=pad(tree.cell_lo, 0), cell_hi=pad(tree.cell_hi, 0),
        cell_key=pad(tree.cell_key, KEY_MAX), cell_depth=pad(
            tree.cell_depth, 0))
    order, num_rows = _rebuild_order(arrays["active"], arrays["cell_key"])
    return dataclasses.replace(tree, **arrays, order=order,
                               num_rows=num_rows)


def free_rows(tree: POrthTree) -> int:
    return int(jnp.sum(~tree.active))


def extract_points(tree: POrthTree):
    R, C, dim = tree.pts.shape
    ok = (tree.valid & tree.active[:, None]).reshape(R * C)
    return tree.pts.reshape(R * C, dim), ok


def compact(tree: POrthTree, capacity_rows: int | None = None) -> POrthTree:
    pts, ok = extract_points(tree)
    return build(pts, tree.root_lo, tree.root_hi, ok, phi=tree.phi,
                 lam=tree.lam, rounds=tree.rounds,
                 capacity_rows=capacity_rows or tree.capacity_rows)
