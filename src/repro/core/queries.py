"""Shared batched query engine over leaf-row indexes.

TPU adaptation of the paper's queries (Sec. 2.2): the best-first kNN with a
priority queue becomes a *chunked frontier traversal* — rows are visited in
ascending order of bbox distance, a running top-k is maintained, and the loop
stops as soon as the next chunk's bbox lower bound exceeds the current k-th
best distance. This is exact (same pruning argument as best-first search) and
fully vectorized over queries via ``vmap``.

Range queries gather candidate rows whose bbox overlaps the query box (fixed
capacity ``max_rows``, with a truncation flag so callers can size it).

The engine only needs the "leaf directory view" every index exposes:
    pts (R, C, D), valid (R, C), active (R,), bbox_lo/hi (R, D)
so P-Orth trees, SPaC trees and the kd/Zd baselines all share it.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .leafstore import BIG


class LeafView(NamedTuple):
    pts: jax.Array      # (R, C, D) float32 or int32
    valid: jax.Array    # (R, C) bool
    active: jax.Array   # (R,) bool
    bbox_lo: jax.Array  # (R, D)
    bbox_hi: jax.Array  # (R, D)


def _f32(x):
    return x.astype(jnp.float32)


def dist2_point_box(q, lo, hi):
    """Squared distance from point q (D,) to boxes (R, D)."""
    d = jnp.maximum(jnp.maximum(_f32(lo) - _f32(q), _f32(q) - _f32(hi)), 0.0)
    return jnp.sum(d * d, axis=-1)


def _knn_single(view: LeafView, q, k: int, chunk: int):
    R, C, dim = view.pts.shape
    n_chunks = (R + chunk - 1) // chunk
    dmin2 = jnp.where(view.active, dist2_point_box(q, view.bbox_lo,
                                                   view.bbox_hi), BIG)
    row_order = jnp.argsort(dmin2).astype(jnp.int32)
    dmin2_sorted = dmin2[row_order]
    pad = n_chunks * chunk - R
    row_order = jnp.pad(row_order, (0, pad), constant_values=0)
    dmin2_sorted = jnp.pad(dmin2_sorted, (0, pad), constant_values=BIG)

    best_d2 = jnp.full((k,), BIG)
    best_id = jnp.full((k,), -1, jnp.int32)

    def cond(state):
        i, best_d2, _ = state
        frontier = jax.lax.dynamic_slice(dmin2_sorted, (i * chunk,), (1,))[0]
        return (i < n_chunks) & (frontier <= best_d2[k - 1])

    def body(state):
        i, best_d2, best_id = state
        rows = jax.lax.dynamic_slice(row_order, (i * chunk,), (chunk,))
        pts = view.pts[rows]                      # (chunk, C, D)
        # mask the tail padding of row_order (pad rows alias row 0 and
        # would re-count its points when the loop reaches the last chunk)
        pos = i * chunk + jnp.arange(chunk, dtype=jnp.int32)
        ok = (view.valid[rows] & view.active[rows][:, None]
              & (pos < R)[:, None])
        diff = _f32(pts) - _f32(q)[None, None, :]
        d2 = jnp.sum(diff * diff, axis=-1)
        d2 = jnp.where(ok, d2, BIG).reshape(-1)
        ids = (rows[:, None] * C + jnp.arange(C, dtype=jnp.int32)[None, :]
               ).reshape(-1)
        cat_d2 = jnp.concatenate([best_d2, d2])
        cat_id = jnp.concatenate([best_id, ids])
        neg, sel = jax.lax.top_k(-cat_d2, k)
        return i + 1, -neg, cat_id[sel]

    _, best_d2, best_id = jax.lax.while_loop(
        cond, body, (jnp.int32(0), best_d2, best_id))
    best_id = jnp.where(best_d2 >= BIG, -1, best_id)
    return best_d2, best_id


def knn_impl(view: LeafView, queries, k: int, chunk: int = 8):
    """Unjitted :func:`knn` — use inside shard_map/pjit regions (a nested
    ``jax.jit`` around the vmapped while_loop miscompiles under shard_map
    on some jax versions; inner jit is a no-op there anyway)."""
    return jax.vmap(lambda q: _knn_single(view, q, k, chunk))(queries)


@functools.partial(jax.jit, static_argnums=(2, 3))
def knn(view: LeafView, queries, k: int, chunk: int = 8):
    """Exact batched k-nearest-neighbors.

    queries: (Q, D). Returns (d2 (Q, k) ascending, flat ids (Q, k) = row*C+slot,
    -1 padded when fewer than k points exist).
    """
    return knn_impl(view, queries, k, chunk)


def gather_points(view: LeafView, flat_ids):
    """Resolve flat ids (row*C+slot) from knn/range_list into coordinates."""
    R, C, dim = view.pts.shape
    safe = jnp.maximum(flat_ids, 0)
    pts = view.pts.reshape(R * C, dim)[safe]
    return jnp.where((flat_ids >= 0)[..., None], pts, 0)


def flatten_view(view: LeafView):
    """Flat (R*C, D) points + validity — the brute-force scan's
    operands. The flat index equals row*C+slot, so ids from a flat kNN
    scan and from the frontier traversal live in the same id space."""
    R, C, dim = view.pts.shape
    ok = (view.valid & view.active[:, None]).reshape(R * C)
    return view.pts.reshape(R * C, dim), ok


def _boxes_overlap(lo_a, hi_a, lo_b, hi_b):
    return jnp.all((_f32(lo_a) <= _f32(hi_b)) & (_f32(lo_b) <= _f32(hi_a)),
                   axis=-1)


def _range_rows(view: LeafView, lo, hi, max_rows: int):
    overlap = _boxes_overlap(view.bbox_lo, view.bbox_hi, lo[None, :],
                             hi[None, :]) & view.active
    R = overlap.shape[0]
    n_overlap = jnp.sum(overlap, dtype=jnp.int32)
    # top_k on the negated selection key picks the same rows, in the
    # same order, as the old full `argsort(key)[:max_rows]` over R —
    # overlapping rows keep key -row (so descending top_k yields row
    # order), the rest collapse to -R and tie-break by lowest index,
    # exactly like the stable argsort — without sorting all R rows.
    # Engine buckets can exceed R; the slice semantics cap at R.
    key = jnp.where(overlap, -jnp.arange(R, dtype=jnp.int32),
                    jnp.int32(-R))
    _, rows = jax.lax.top_k(key, min(int(max_rows), R))
    rows = rows.astype(jnp.int32)
    rows_ok = overlap[rows]
    truncated = n_overlap > max_rows
    return rows, rows_ok, truncated


def _range_count_single(view: LeafView, lo, hi, max_rows: int):
    rows, rows_ok, truncated = _range_rows(view, lo, hi, max_rows)
    pts = view.pts[rows]
    inside = (jnp.all((_f32(pts) >= _f32(lo)) & (_f32(pts) <= _f32(hi)),
                      axis=-1)
              & view.valid[rows] & rows_ok[:, None])
    return jnp.sum(inside, dtype=jnp.int32), truncated


def range_count_impl(view: LeafView, lo, hi, max_rows: int = 128):
    """Unjitted :func:`range_count` — use inside shard_map/pjit regions."""
    return jax.vmap(lambda l, h: _range_count_single(view, l, h, max_rows))(
        lo, hi)


@functools.partial(jax.jit, static_argnums=(3,))
def range_count(view: LeafView, lo, hi, max_rows: int = 128):
    """Exact batched range-count. lo/hi: (Q, D) inclusive boxes.

    Returns (counts (Q,), truncated (Q,)); a True truncated flag means
    max_rows was too small for exactness (resize and re-run)."""
    return range_count_impl(view, lo, hi, max_rows)


def _range_list_single(view: LeafView, lo, hi, max_rows: int, cap: int):
    R, C, dim = view.pts.shape
    rows, rows_ok, truncated = _range_rows(view, lo, hi, max_rows)
    pts = view.pts[rows]
    inside = (jnp.all((_f32(pts) >= _f32(lo)) & (_f32(pts) <= _f32(hi)),
                      axis=-1)
              & view.valid[rows] & rows_ok[:, None])
    flat_in = inside.reshape(-1)
    flat_ids = (rows[:, None] * C
                + jnp.arange(C, dtype=jnp.int32)[None, :]).reshape(-1)
    # stable compaction of hits to the front
    key = jnp.where(flat_in, jnp.arange(flat_in.shape[0], dtype=jnp.int32),
                    jnp.int32(flat_in.shape[0]))
    sel = jnp.argsort(key)[:cap]
    ids = jnp.where(flat_in[sel], flat_ids[sel], -1)
    count = jnp.sum(flat_in, dtype=jnp.int32)
    return ids, count, truncated


def range_list_impl(view: LeafView, lo, hi, max_rows: int = 128,
                    cap: int = 512):
    """Unjitted range-report with the *row* truncation flag kept
    separate from output-capacity overflow: (ids, counts, rows_trunc).

    ``counts`` is exact whenever rows_trunc is False, even if it
    exceeds ``cap`` — the engine escalates the two buffers
    independently off these signals."""
    return jax.vmap(
        lambda l, h: _range_list_single(view, l, h, max_rows, cap))(lo, hi)


@functools.partial(jax.jit, static_argnums=(3, 4))
def range_list(view: LeafView, lo, hi, max_rows: int = 128, cap: int = 512):
    """Exact batched range-report with fixed output capacity.

    Returns (ids (Q, cap) flat row*C+slot padded with -1, counts (Q,),
    truncated (Q,))."""
    ids, count, rows_trunc = range_list_impl(view, lo, hi, max_rows, cap)
    return ids, count, rows_trunc | (count > cap)
