"""Baseline indexes the paper compares against (Sec. 5, Fig. 3).

* ``kd``  — parallel kd-tree with object-median splits, built level-wise
  (BHL-tree style [62]); batch updates are full rebuilds, its documented
  update strategy. (The Pkd-tree's sampled-median + sieve construction is
  what P-Orth borrows; the kd baseline here isolates *query* behaviour of
  median splits.)
* ``zd``  — Zd-tree-like orth-tree built by materializing Morton codes and
  sorting them up front [16]. Structurally identical to the P-Orth tree;
  the cost difference against ``porth.build`` is exactly the paper's claim
  that the sieve avoids the encode+sort passes.
* CPAM-like total-order SPaC is ``spac.insert(..., sort_rows=True)``.

Both baselines expose the shared LeafView, so the query engine and all
query benchmarks run on them unchanged.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import sfc
from .leafstore import scatter_to_rows, segment_bbox
from .porth import _group_stats
from .queries import LeafView

KEY_MAX = np.uint32(0xFFFFFFFF)  # numpy: keep import device-free


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["pts", "valid", "count", "active", "bbox_lo", "bbox_hi"],
    meta_fields=["phi"])
@dataclasses.dataclass(frozen=True)
class LeafIndex:
    """Minimal static leaf-directory index (kd / zd baselines)."""
    pts: Any
    valid: Any
    count: Any
    active: Any
    bbox_lo: Any
    bbox_hi: Any
    phi: int = 32

    def view(self) -> LeafView:
        return LeafView(self.pts, self.valid, self.active, self.bbox_lo,
                        self.bbox_hi)

    @property
    def size(self):
        return jnp.sum(jnp.where(self.active, self.count, 0))


def _finalize_groups(points, ok, key, phi: int, R: int):
    """Chunk sorted groups into rows of phi (same chunking as porth)."""
    n, dim = points.shape
    gid, cnt, pos = _group_stats(jnp.where(ok, key, KEY_MAX), ok)
    rows_per = (cnt + phi - 1) // phi
    change = jnp.concatenate([jnp.ones((1,), bool), gid[1:] != gid[:-1]])
    per_group = jnp.where(change, rows_per, 0)
    incl = jnp.cumsum(per_group)
    goff = (incl - per_group)[jnp.searchsorted(gid, gid, side="left")]
    row = goff.astype(jnp.int32) + pos // phi
    slot = pos % phi
    in_new = ok & (row < R)
    C = 2 * phi
    pts_rows = scatter_to_rows(jnp.zeros((R, C, dim), points.dtype),
                               row, slot, points, in_new)
    valid_rows = scatter_to_rows(jnp.zeros((R, C), bool), row, slot,
                                 jnp.ones(n, bool), in_new)
    count = jnp.zeros(R, jnp.int32).at[
        jnp.where(in_new, row, R)].add(1, mode="drop")
    lo, hi = segment_bbox(points, jnp.where(in_new, row, R), in_new, R)
    return LeafIndex(pts=pts_rows, valid=valid_rows, count=count,
                     active=count > 0, bbox_lo=lo, bbox_hi=hi, phi=phi)


# ---------------------------------------------------------------------------
# kd-tree: object-median splits, level-synchronous construction
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("phi", "max_depth",
                                             "capacity_rows"))
def kd_build(points, mask=None, *, phi: int = 32, max_depth: int = 24,
             capacity_rows: int | None = None) -> LeafIndex:
    n, dim = points.shape
    if mask is None:
        mask = jnp.ones(n, bool)
    if capacity_rows is None:
        capacity_rows = max(4 * ((n + phi - 1) // phi), 16)
    R = capacity_rows

    key = jnp.zeros(n, jnp.uint32)   # path code: 1 bit per level
    pts, ok = points, mask
    for d in range(max_depth):
        skey = jnp.where(ok, key, KEY_MAX)
        # two stable sorts: by coord then by segment => within-segment sorted
        coord = pts[:, d % dim]
        p1 = jnp.argsort(coord, stable=True).astype(jnp.int32)
        pts, ok, key, skey = pts[p1], ok[p1], key[p1], skey[p1]
        p2 = jnp.argsort(skey, stable=True).astype(jnp.int32)
        pts, ok, key, skey = pts[p2], ok[p2], key[p2], skey[p2]
        _, cnt, pos = _group_stats(skey, ok)
        act = ok & (cnt > phi)
        bit = (pos >= (cnt + 1) // 2).astype(jnp.uint32)  # median split
        key = jnp.where(act, (key << 1) | bit, key << 1)
    skey = jnp.where(ok, key, KEY_MAX)
    perm = jnp.argsort(skey, stable=True).astype(jnp.int32)
    return _finalize_groups(pts[perm], ok[perm], skey[perm], phi, R)


def _live_flat(index: LeafIndex):
    R, C, dim = index.pts.shape
    pts = index.pts.reshape(R * C, dim)
    ok = (index.valid & index.active[:, None]).reshape(R * C)
    return pts, ok


def kd_insert(index: LeafIndex, new_pts, new_mask=None, **kw) -> LeafIndex:
    """BHL-tree semantics: batch update = full rebuild."""
    old, ok = _live_flat(index)
    if new_mask is None:
        new_mask = jnp.ones(new_pts.shape[0], bool)
    pts = jnp.concatenate([old, new_pts.astype(old.dtype)], axis=0)
    mask = jnp.concatenate([ok, new_mask])
    return kd_build(pts, mask, phi=index.phi, **kw)


def multiset_subtract_mask(live_pts, live_ok, del_pts, del_ok=None):
    """keep-mask over live_pts after removing the del_pts multiset.

    Segmented-scan formulation (no 64-bit key packing): lexsort live+del
    together, group equal coordinates, drop as many live copies per group
    as there are delete entries. Returns the keep mask aligned to live_pts.
    """
    dim = live_pts.shape[1]
    n, m = live_pts.shape[0], del_pts.shape[0]
    if del_ok is None:
        del_ok = jnp.ones(m, bool)
    allp = jnp.concatenate([live_pts, del_pts.astype(live_pts.dtype)], 0)
    is_live = jnp.concatenate([jnp.ones(n, bool), jnp.zeros(m, bool)])
    okv = jnp.concatenate([live_ok, del_ok])
    order = jnp.lexsort([allp[:, k] for k in range(dim - 1, -1, -1)])
    sp, sl, so = allp[order], is_live[order], okv[order]
    idx = jnp.arange(n + m, dtype=jnp.int32)
    newrun = jnp.concatenate([jnp.ones((1,), bool),
                              jnp.any(sp[1:] != sp[:-1], axis=-1)])
    runstart = jax.lax.associative_scan(jnp.maximum,
                                        jnp.where(newrun, idx, 0))
    # deletes per run, broadcast to members via segmented sum
    is_del = (~sl) & so
    cdel = jnp.cumsum(is_del.astype(jnp.int32))
    cdel_start = jnp.where(runstart > 0, cdel[jnp.maximum(runstart - 1, 0)],
                           0)
    run_id = jnp.cumsum(newrun.astype(jnp.int32)) - 1
    run_dels = jnp.zeros(n + m, jnp.int32).at[run_id].add(
        is_del.astype(jnp.int32))[run_id]
    # live rank within run (valid lives only)
    is_lv = sl & so
    clive = jnp.cumsum(is_lv.astype(jnp.int32))
    clive_start = jnp.where(runstart > 0,
                            clive[jnp.maximum(runstart - 1, 0)], 0)
    live_rank = clive - clive_start - 1  # for live entries
    keep_sorted = is_lv & (live_rank >= run_dels)
    keep = jnp.zeros(n + m, bool).at[order].set(keep_sorted)
    return keep[:n]


def kd_delete(index: LeafIndex, del_pts, del_mask=None, **kw) -> LeafIndex:
    """Full rebuild without the deleted multiset (rank-matched)."""
    old, ok = _live_flat(index)
    keep = multiset_subtract_mask(old, ok, del_pts, del_mask)
    return kd_build(old, keep, phi=index.phi, **kw)


# ---------------------------------------------------------------------------
# Zd-tree-like: explicit Morton presort, then orth structure from codes
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("phi", "bits", "coord_bits",
                                             "lam", "capacity_rows"))
def zd_build(points, mask=None, *, phi: int = 32, bits: int = 15,
             coord_bits: int = 20, lam: int = 3,
             capacity_rows: int | None = None) -> LeafIndex:
    """Materialize Morton codes, sort them, then reveal lam*D bits per round
    to derive the orth leaf cells — the extra encode pass + full-precision
    sort is exactly the overhead P-Orth avoids (paper Sec. 3, 'Issues')."""
    n, dim = points.shape
    if mask is None:
        mask = jnp.ones(n, bool)
    if capacity_rows is None:
        capacity_rows = max(min(2 * n, 8 * ((n + phi - 1) // phi)), 16)
    shift = max(0, coord_bits - bits)
    codes = sfc.morton_encode(points.astype(jnp.uint32) >> shift, bits)
    skey = jnp.where(mask, codes, KEY_MAX)
    perm = jnp.argsort(skey, stable=True).astype(jnp.int32)
    pts, ok, codes = points[perm], mask[perm], skey[perm]

    total_bits = bits * dim
    key = jnp.zeros(n, jnp.uint32)  # revealed prefix
    depth_bits = jnp.zeros(n, jnp.int32)
    rounds = (total_bits + lam * dim - 1) // (lam * dim)
    for _ in range(rounds):
        _, cnt, _ = _group_stats(jnp.where(ok, key, KEY_MAX), ok)
        act = ok & (cnt > phi) & (depth_bits < total_bits)
        nb = jnp.minimum(lam * dim, total_bits - depth_bits)
        newly = (codes >> jnp.maximum(
            total_bits - depth_bits - nb, 0).astype(jnp.uint32))
        mask_keep = (jnp.uint32(1) << nb.astype(jnp.uint32)) - 1
        key = jnp.where(act, (key << nb.astype(jnp.uint32))
                        | (newly & mask_keep), key)
        depth_bits = jnp.where(act, depth_bits + nb, depth_bits)
        # already sorted by full code => groups remain contiguous, no re-sort
    # normalize keys to a common shift for grouping
    fkey = jnp.where(ok, key << (total_bits - depth_bits).astype(jnp.uint32),
                     KEY_MAX)
    # groups share prefix but may differ in depth — disjoint cells, distinct
    # lo-corners, and the array is already in code order => contiguous.
    return _finalize_groups(pts, ok, fkey, phi, capacity_rows)


def zd_insert(index: LeafIndex, new_pts, new_mask=None, **kw) -> LeafIndex:
    """Merge-rebuild update (labeled as such in benchmarks — the original
    Zd update algorithm is not reproduced here; this baseline isolates the
    construction-cost claim)."""
    old, ok = _live_flat(index)
    if new_mask is None:
        new_mask = jnp.ones(new_pts.shape[0], bool)
    pts = jnp.concatenate([old, new_pts.astype(old.dtype)], axis=0)
    mask = jnp.concatenate([ok, new_mask])
    return zd_build(pts, mask, phi=index.phi, **kw)


def zd_delete(index: LeafIndex, del_pts, del_mask=None, **kw) -> LeafIndex:
    """Merge-rebuild without the deleted multiset (rank-matched)."""
    old, ok = _live_flat(index)
    keep = multiset_subtract_mask(old, ok, del_pts, del_mask)
    return zd_build(old, keep, phi=index.phi, **kw)
