"""Distributed dynamic spatial index: the paper's workload at pod scale.

The index is *SFC-range partitioned* over a mesh axis via shard_map —
the multi-node analogue of the paper's shared-memory design:

  * splitters — each shard samples local SFC codes; samples all_gather
    and quantile splitters define per-shard key ranges (the same
    sample-based partitioning the paper's HybridSort uses per node).
  * routing — updates compute codes, searchsorted against splitters,
    pack into fixed-capacity per-destination slabs, and exchange with
    ONE all_to_all (the cross-chip counterpart of the sieve's
    one-round data movement; per-pair capacity + overflow counter
    replace dynamic allocation).
  * local index — each shard owns an independent SPaC-tree (or P-Orth
    tree) over its key range; batch insert/delete are the paper's
    algorithms unchanged.
  * queries — kNN fans out (queries replicated), each shard answers
    exactly from its range, and a top-k merge over an all_gather
    combines candidates; exact because shards partition the point set.
    Range-count is a local count + psum.

At 1000+ nodes the axis simply grows; nothing here depends on the
shard count. Skew (the paper's Varden/Sweepline) shows up as routing
imbalance: the `dropped` counter reports slab overflow so callers can
re-shard with a larger slack — tested in tests/test_distributed.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import queries as Q
from . import spac
from .leafstore import BIG, group_occurrence

try:                      # jax >= 0.6 spells it jax.shard_map
    shard_map = jax.shard_map
except AttributeError:    # pragma: no cover
    from jax.experimental.shard_map import shard_map

P = jax.sharding.PartitionSpec

CODE_MAX = jnp.uint32(0xFFFFFFFF)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["tree", "splitters", "dropped"],
    meta_fields=["axis"])
@dataclasses.dataclass(frozen=True)
class DistIndex:
    tree: Any          # SpacTree pytree, leaves stacked (n_shards, ...)
    splitters: Any     # (n_shards - 1,) uint32, replicated
    dropped: Any       # () int32 — points lost to slab overflow (0 = ok)
    axis: str = "data"


def _unstack(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _stack(tree):
    return jax.tree.map(lambda a: a[None], tree)


def _sample_splitters(codes, mask, axis, n_shards, n_samples=256):
    """Deterministic quantile splitters from sorted local samples."""
    key = jnp.where(mask, codes, CODE_MAX)
    srt = jnp.sort(key)
    n = srt.shape[0]
    stride = max(n // n_samples, 1)
    local = srt[::stride][:n_samples]
    if local.shape[0] < n_samples:
        local = jnp.pad(local, (0, n_samples - local.shape[0]),
                        constant_values=CODE_MAX)
    allv = jnp.sort(jax.lax.all_gather(local, axis).reshape(-1))
    total = allv.shape[0]
    idx = (jnp.arange(1, n_shards) * total) // n_shards
    return allv[idx]


def _pack(pts, mask, bucket, n_shards: int, cap: int):
    """Pack rows into per-destination slabs (n_shards*cap, ...)."""
    n, dim = pts.shape
    key = jnp.where(mask, bucket, n_shards)
    perm = jnp.argsort(key, stable=True).astype(jnp.int32)
    sb, sp, sm = key[perm], pts[perm], mask[perm]
    occ = group_occurrence(sb)
    keep = sm & (occ < cap)
    slot = jnp.where(keep, sb * cap + occ, n_shards * cap)
    send_pts = jnp.zeros((n_shards * cap, dim), pts.dtype
                         ).at[slot].set(sp, mode="drop")
    send_mask = jnp.zeros((n_shards * cap,), bool
                          ).at[slot].set(keep, mode="drop")
    return send_pts, send_mask, jnp.sum(sm & ~keep, dtype=jnp.int32)


def _route_exchange(pts, mask, splitters, axis, n_shards: int, cap: int,
                    curve: str, bits: int, coord_bits: int):
    codes = spac._encode(pts.astype(jnp.int32), curve, bits, coord_bits)
    bucket = jnp.searchsorted(splitters, codes, side="right"
                              ).astype(jnp.int32)
    send_p, send_m, dropped = _pack(pts.astype(jnp.int32), mask, bucket,
                                    n_shards, cap)
    recv_p = jax.lax.all_to_all(send_p.reshape(n_shards, cap, -1), axis,
                                split_axis=0, concat_axis=0)
    recv_m = jax.lax.all_to_all(send_m.reshape(n_shards, cap), axis,
                                split_axis=0, concat_axis=0)
    dim = pts.shape[1]
    return (recv_p.reshape(n_shards * cap, dim),
            recv_m.reshape(n_shards * cap),
            jax.lax.psum(dropped, axis))


def _smap(fn, mesh, in_specs, out_specs):
    try:
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:  # jax < 0.6 spells the replication check check_rep
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


# ----------------------------------------------------------------- build

def build(points, mesh, mask=None, *, axis: str = "data", phi: int = 32,
          curve: str = "hilbert", bits: int = 16, coord_bits: int = 30,
          capacity_rows: int | None = None, slack: float = 2.0,
          n_samples: int = 256) -> DistIndex:
    """points: (N, dim) sharded on dim 0 over `axis` (or host array —
    jax will split it). Returns a DistIndex with one SPaC shard per
    device along `axis`."""
    n, dim = points.shape
    n_shards = mesh.shape[axis]
    n_local = n // n_shards
    cap = int(n_local * slack / n_shards) + 8
    if capacity_rows is None:
        capacity_rows = max(4 * ((n_shards * cap + phi - 1) // phi), 8)
    if mask is None:
        mask = jnp.ones(n, bool)

    def local(pts, msk):
        codes = spac._encode(pts.astype(jnp.int32), curve, bits,
                             coord_bits)
        splitters = _sample_splitters(codes, msk, axis, n_shards,
                                      n_samples)
        rp, rm, dropped = _route_exchange(pts, msk, splitters, axis,
                                          n_shards, cap, curve, bits,
                                          coord_bits)
        # _impl spelling: a jitted callee here would nest jax.jit under
        # shard_map, the jax 0.4.x miscompile class (wrong results on
        # shards != 0); shard_map's own trace is the only jit we want
        tree = spac.build_impl(rp, rm, phi=phi, curve=curve, bits=bits,
                               coord_bits=coord_bits,
                               capacity_rows=capacity_rows)
        return _stack(tree), splitters, dropped

    tree, splitters, dropped = _smap(
        local, mesh, in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(), P()))(points, mask)
    return DistIndex(tree=tree, splitters=splitters, dropped=dropped,
                     axis=axis)


# --------------------------------------------------------------- updates

def _update(index: DistIndex, pts, mask, mesh, op: str, slack: float):
    axis = index.axis
    n_shards = mesh.shape[axis]
    meta = _tree_meta(index)
    m = pts.shape[0]
    cap = int((m // n_shards) * slack / n_shards) + 8
    if mask is None:
        mask = jnp.ones(m, bool)

    def local(tree, p, k):
        tree = _unstack(tree)
        rp, rm, dropped = _route_exchange(
            p, k, index.splitters, axis, n_shards, cap,
            meta["curve"], meta["bits"], meta["coord_bits"])
        # _impl spellings: delete's while_loop under a nested jit is the
        # documented jax 0.4.x shard_map miscompile; insert matches for
        # symmetry (and to keep one trace instead of two)
        if op == "insert":
            tree = spac.insert_impl(tree, rp, rm, max_overflow_rows=min(
                64, tree.capacity_rows))
        else:
            tree = spac.delete_impl(tree, rp, rm)
        return _stack(tree), dropped

    tree, dropped = _smap(
        local, mesh, in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P()))(index.tree, pts, mask)
    return dataclasses.replace(index, tree=tree,
                               dropped=index.dropped + dropped)


def insert(index: DistIndex, pts, mesh, mask=None, *, slack: float = 2.0):
    return _update(index, pts, mask, mesh, "insert", slack)


def delete(index: DistIndex, pts, mesh, mask=None, *, slack: float = 2.0):
    return _update(index, pts, mask, mesh, "delete", slack)


def _tree_meta(index: DistIndex):
    t = index.tree
    return dict(curve=t.curve, bits=t.bits, coord_bits=t.coord_bits)


# --------------------------------------------------------------- queries

def knn(index: DistIndex, qpts, k: int, mesh, chunk: int = 8,
        impl: str = "frontier", kernel: str = "auto"):
    """Exact distributed kNN. qpts: (Q, dim) replicated. Returns
    (d2 (Q, k) ascending, points (Q, k, dim), valid (Q, k)).

    ``impl="frontier"`` runs the chunked frontier traversal per shard;
    ``impl="pallas-frontier"`` the fused frontier kernel;
    ``impl="flat"`` the brute-force scan (``kernel`` picks the kernel
    flavor: auto/pallas/pallas-interpret/ref). All use the unjitted
    ``_impl`` spellings — required inside shard_map (miscompile note in
    ROADMAP.md)."""
    from ..kernels.frontier import ops as frontier_ops
    from ..kernels.knn import ops as knn_ops
    axis = index.axis

    def local(tree, q):
        tree = _unstack(tree)
        view = tree.view()
        if impl == "frontier":
            d2, ids = Q.knn_impl(view, q, k, chunk)
        elif impl == "pallas-frontier":
            d2, ids = frontier_ops.knn_frontier_impl(
                view.pts, view.valid, view.active, view.bbox_lo,
                view.bbox_hi, q, k=k, impl=kernel)
        else:
            flat_pts, flat_ok = Q.flatten_view(view)
            d2, ids = knn_ops.knn_bruteforce_impl(q, flat_pts, flat_ok,
                                                  k=k, impl=kernel)
        pts = Q.gather_points(view, ids)
        d2 = jnp.where(ids >= 0, d2, BIG)
        all_d2 = jax.lax.all_gather(d2, axis)     # (S, Q, k)
        all_pts = jax.lax.all_gather(pts, axis)   # (S, Q, k, dim)
        S = all_d2.shape[0]
        qn = q.shape[0]
        cat_d2 = all_d2.transpose(1, 0, 2).reshape(qn, S * k)
        cat_pts = all_pts.transpose(1, 0, 2, 3).reshape(qn, S * k, -1)
        neg, sel = jax.lax.top_k(-cat_d2, k)
        best = jnp.take_along_axis(cat_pts, sel[..., None], axis=1)
        return -neg, best, (-neg) < BIG

    return _smap(local, mesh, in_specs=(P(axis), P()),
                 out_specs=(P(), P(), P()))(index.tree, qpts)


def range_count(index: DistIndex, lo, hi, mesh, max_rows: int = 128):
    """Exact distributed range-count: local count + psum."""
    axis = index.axis

    def local(tree, lo, hi):
        tree = _unstack(tree)
        cnt, trunc = Q.range_count_impl(tree.view(), lo, hi, max_rows)
        return (jax.lax.psum(cnt, axis),
                jax.lax.psum(trunc.astype(jnp.int32), axis) > 0)

    return _smap(local, mesh, in_specs=(P(axis), P(), P()),
                 out_specs=(P(), P()))(index.tree, lo, hi)


def size(index: DistIndex) -> jax.Array:
    t = index.tree
    return jnp.sum(jnp.where(t.active, t.count, 0))
