"""Distributed dynamic spatial index: the paper's workload at pod scale.

The index is *key-range partitioned* over a mesh axis via shard_map —
the multi-node analogue of the paper's shared-memory design:

  * splitters — each shard samples local routing keys; samples
    all_gather and quantile splitters define per-shard key ranges (the
    same sample-based partitioning the paper's HybridSort uses per
    node). The routing key is backend-specific but always a uint32 SFC
    code: ``spac`` encodes the curve (Hilbert/Morton), ``porth`` uses
    the sieve's prefix keys (:func:`repro.core.porth.point_keys` — they
    *are* Morton codes, computed by midpoint comparisons, so float
    coordinates route exactly like the paper's 'Applicability' claim).
  * routing — updates compute keys, searchsorted against splitters,
    pack into fixed-capacity per-destination slabs, and exchange with
    ONE all_to_all (the cross-chip counterpart of the sieve's
    one-round data movement; per-pair capacity + overflow counter
    replace dynamic allocation).
  * local index — each shard owns an independent SPaC-tree or P-Orth
    tree over its key range; batch insert/delete are the paper's
    algorithms unchanged.
  * queries — kNN fans out (queries replicated), each shard answers
    exactly from its range, and a top-k merge over an all_gather
    combines candidates; exact because shards partition the point set.
    Range-count is a local count + psum.

Every collective program here is built by an ``lru_cache`` closure
factory returning ``jax.jit(shard_map(local))`` — jit *around* the
shard region (the one legal nesting direction on jax 0.4.x; a jit
*inside* would hit the nested-jit miscompile, which is why every local
call is an unjitted ``*_impl`` spelling). The serving hot path
(``SpatialServer`` over a :class:`repro.core.index.DistributedIndex`)
therefore dispatches updates and coalesced queries with zero retraces
after warmup — the query closures bump ``repro.core.engine``'s trace
counter so tests can assert that bound across the exchange.

At 1000+ nodes the axis simply grows; nothing here depends on the
shard count. Skew (the paper's Varden/Sweepline) shows up as routing
imbalance: the `dropped` counter reports slab overflow so callers can
re-shard with a larger slack — tested in tests/test_distributed.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from . import engine as _engine
from . import porth
from . import queries as Q
from . import spac
from .leafstore import BIG, group_occurrence

try:                      # jax >= 0.6 spells it jax.shard_map
    shard_map = jax.shard_map
except AttributeError:    # pragma: no cover
    from jax.experimental.shard_map import shard_map

P = jax.sharding.PartitionSpec

CODE_MAX = np.uint32(0xFFFFFFFF)  # numpy: keep import device-free


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["tree", "splitters", "dropped"],
    meta_fields=["axis", "kind", "ckey"])
@dataclasses.dataclass(frozen=True)
class DistIndex:
    tree: Any          # backend pytree, leaves stacked (n_shards, ...)
    splitters: Any     # (n_shards - 1,) uint32, replicated
    dropped: Any       # () int32 — points lost to slab overflow (0 = ok)
    axis: str = "data"
    kind: str = "spac"          # routing-key family: "spac" | "porth"
    # hashable routing-key params (spac: curve/bits/coord_bits; porth:
    # root_lo/root_hi tuples + lam/rounds) — static meta so dispatch
    # closures can key their cache without a device read
    ckey: tuple = (("bits", 16), ("coord_bits", 30),
                   ("curve", "hilbert"))


def _unstack(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _stack(tree):
    return jax.tree.map(lambda a: a[None], tree)


def _codes(pts, kind: str, kw: dict):
    """Routing key of each point (uint32): the backend's SFC spelling."""
    if kind == "porth":
        root_lo = jnp.asarray(kw["root_lo"], pts.dtype)
        root_hi = jnp.asarray(kw["root_hi"], pts.dtype)
        return porth.point_keys(pts, root_lo, root_hi, lam=kw["lam"],
                                rounds=kw["rounds"])
    return spac._encode(pts.astype(jnp.int32), kw["curve"], kw["bits"],
                        kw["coord_bits"])


def _coerce(pts, kind: str):
    """spac shards store int32 coordinates; porth keeps the caller's
    dtype (float routing is the orth tree's applicability win)."""
    return pts if kind == "porth" else pts.astype(jnp.int32)


def _sample_splitters(codes, mask, axis, n_shards, n_samples=256):
    """Deterministic quantile splitters from sorted local samples.

    Each shard contributes exactly ``n_samples`` codes drawn evenly
    (with replacement when it holds fewer valid rows) from the *valid*
    prefix of its locally sorted codes. Padding the sample with
    CODE_MAX sentinels instead would shift the top quantiles to
    CODE_MAX whenever a shard holds fewer than ``n_samples`` rows and
    leave the last shards empty."""
    key = jnp.where(mask, codes, CODE_MAX)
    srt = jnp.sort(key)
    v = jnp.maximum(jnp.sum(mask, dtype=jnp.int32), 1)
    pos = (jnp.arange(n_samples, dtype=jnp.int32) * v) // n_samples
    local = srt[pos]
    allv = jnp.sort(jax.lax.all_gather(local, axis).reshape(-1))
    total = allv.shape[0]
    idx = (jnp.arange(1, n_shards) * total) // n_shards
    return allv[idx]


def _pack(pts, mask, bucket, n_shards: int, cap: int):
    """Pack rows into per-destination slabs (n_shards*cap, ...)."""
    n, dim = pts.shape
    key = jnp.where(mask, bucket, n_shards)
    perm = jnp.argsort(key, stable=True).astype(jnp.int32)
    sb, sp, sm = key[perm], pts[perm], mask[perm]
    occ = group_occurrence(sb)
    keep = sm & (occ < cap)
    slot = jnp.where(keep, sb * cap + occ, n_shards * cap)
    send_pts = jnp.zeros((n_shards * cap, dim), pts.dtype
                         ).at[slot].set(sp, mode="drop")
    send_mask = jnp.zeros((n_shards * cap,), bool
                          ).at[slot].set(keep, mode="drop")
    return send_pts, send_mask, jnp.sum(sm & ~keep, dtype=jnp.int32)


def _route_exchange(pts, mask, splitters, axis, n_shards: int, cap: int,
                    kind: str, kw: dict):
    codes = _codes(pts, kind, kw)
    bucket = jnp.searchsorted(splitters, codes, side="right"
                              ).astype(jnp.int32)
    send_p, send_m, dropped = _pack(_coerce(pts, kind), mask, bucket,
                                    n_shards, cap)
    recv_p = jax.lax.all_to_all(send_p.reshape(n_shards, cap, -1), axis,
                                split_axis=0, concat_axis=0)
    recv_m = jax.lax.all_to_all(send_m.reshape(n_shards, cap), axis,
                                split_axis=0, concat_axis=0)
    dim = pts.shape[1]
    return (recv_p.reshape(n_shards * cap, dim),
            recv_m.reshape(n_shards * cap),
            jax.lax.psum(dropped, axis))


def _smap(fn, mesh, in_specs, out_specs):
    try:
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:  # jax < 0.6 spells the replication check check_rep
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def _pad_rows(pts, mask, n_shards: int):
    """Pad the leading (sharded) dim to a multiple of the shard count —
    shape metadata only, so dispatch paths stay host-sync-free."""
    m = pts.shape[0]
    if mask is None:
        mask = jnp.ones(m, bool)
    pad = (-m) % n_shards
    if pad:
        pts = jnp.concatenate(
            [pts, jnp.zeros((pad, pts.shape[1]), pts.dtype)])
        mask = jnp.concatenate([mask, jnp.zeros(pad, bool)])
    return pts, mask


# ---------------------------------------------------------------- closures
#
# Every collective program is cached here: jax.jit wraps the *outside*
# of the shard_map region (the only legal direction — see module
# docstring), keyed on the static routing/shape params. The local fns
# count traces so serving tests can pin the no-retrace contract.

@functools.lru_cache(maxsize=None)
def _build_closure(mesh, axis: str, n_shards: int, cap: int, kind: str,
                   phi: int, capacity_rows: int, n_samples: int,
                   ckey: tuple):
    obs.count("dist.plan_miss")
    kw = dict(ckey)

    def local(pts, msk):
        obs.count("dist.update_trace")
        codes = _codes(pts, kind, kw)
        splitters = _sample_splitters(codes, msk, axis, n_shards,
                                      n_samples)
        rp, rm, dropped = _route_exchange(pts, msk, splitters, axis,
                                          n_shards, cap, kind, kw)
        # _impl spellings: a jitted callee here would nest jax.jit under
        # shard_map, the jax 0.4.x miscompile class (wrong results on
        # shards != 0); shard_map's own trace is the only jit we want
        if kind == "porth":
            tree = porth.build_impl(
                rp, jnp.asarray(kw["root_lo"], rp.dtype),
                jnp.asarray(kw["root_hi"], rp.dtype), rm, phi=phi,
                lam=kw["lam"], rounds=kw["rounds"],
                capacity_rows=capacity_rows)
        else:
            tree = spac.build_impl(rp, rm, phi=phi, curve=kw["curve"],
                                   bits=kw["bits"],
                                   coord_bits=kw["coord_bits"],
                                   capacity_rows=capacity_rows)
        return _stack(tree), splitters, dropped

    return jax.jit(_smap(local, mesh, in_specs=(P(axis), P(axis)),
                         out_specs=(P(axis), P(), P())))


@functools.lru_cache(maxsize=None)
def _update_closure(mesh, axis: str, n_shards: int, cap: int, kind: str,
                    op: str, mor: int, ckey: tuple):
    obs.count("dist.plan_miss")
    kw = dict(ckey)

    def local(tree, p, k, splitters):
        obs.count("dist.update_trace")
        tree = _unstack(tree)
        rp, rm, dropped = _route_exchange(p, k, splitters, axis,
                                          n_shards, cap, kind, kw)
        # _impl spellings: delete's while_loop under a nested jit is the
        # documented jax 0.4.x shard_map miscompile; insert matches for
        # symmetry (and to keep one trace instead of two)
        if op == "insert":
            tree = (porth.insert_impl(tree, rp, rm,
                                      max_overflow_rows=mor)
                    if kind == "porth" else
                    spac.insert_impl(tree, rp, rm,
                                     max_overflow_rows=mor))
        else:
            tree = (porth.delete_impl(tree, rp, rm) if kind == "porth"
                    else spac.delete_impl(tree, rp, rm))
        return _stack(tree), dropped

    return jax.jit(_smap(
        local, mesh, in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=(P(axis), P())))


# Query closures deliberately do NOT use shard_map. Queries need no
# routing — every shard answers over its whole subtree and a global
# merge combines candidates — so they can be spelled as a plain jitted
# vmap over the stacked shard axis. GSPMD then partitions each vmap
# lane onto its device (the tree leaves are sharded on that axis) and
# inserts the gather for the merge itself. That keeps queries on the
# standard SPMD compile path: under manual partitioning
# (jit-around-shard_map, check_rep=False) the frontier traversal's
# vmapped while_loop with a loop-carried exit bound miscompiles on
# shards != 0 on jax 0.4.x — empirically isolated; update closures
# avoid it because their while_loops are unbatched — and the vmap
# spelling sidesteps the whole class while staying cached + exact.

@functools.lru_cache(maxsize=None)
def _knn_closure(k: int, impl: str, kernel: str, chunk: int):
    obs.count("dist.plan_miss")
    from ..kernels.frontier import ops as frontier_ops
    from ..kernels.knn import ops as knn_ops

    def run(tree, q):
        # trace-time counter: same contract as the engine's local query
        # closures, so the O(log) retrace bound is assertable across
        # the distributed merge too
        _engine._STATS["traces"] += 1
        obs.count("engine.trace")

        def one(shard_tree):
            view = shard_tree.view()
            if impl == "frontier":
                d2, ids = Q.knn_impl(view, q, k, chunk)
            elif impl == "pallas-frontier":
                d2, ids = frontier_ops.knn_frontier_impl(
                    view.pts, view.valid, view.active, view.bbox_lo,
                    view.bbox_hi, q, k=k, impl=kernel)
            else:
                flat_pts, flat_ok = Q.flatten_view(view)
                d2, ids = knn_ops.knn_bruteforce_impl(
                    q, flat_pts, flat_ok, k=k, impl=kernel)
            pts = Q.gather_points(view, ids)
            return jnp.where(ids >= 0, d2, BIG), pts

        all_d2, all_pts = jax.vmap(one)(tree)     # (S, Q, k), (S, Q, k, d)
        S, qn, _ = all_d2.shape
        cat_d2 = all_d2.transpose(1, 0, 2).reshape(qn, S * k)
        cat_pts = all_pts.transpose(1, 0, 2, 3).reshape(qn, S * k, -1)
        neg, sel = jax.lax.top_k(-cat_d2, k)
        best = jnp.take_along_axis(cat_pts, sel[..., None], axis=1)
        return -neg, best, (-neg) < BIG

    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _range_count_closure(max_rows: int):
    obs.count("dist.plan_miss")

    def run(tree, lo, hi):
        _engine._STATS["traces"] += 1
        obs.count("engine.trace")
        cnt, trunc = jax.vmap(
            lambda st: Q.range_count_impl(st.view(), lo, hi, max_rows)
        )(tree)
        return jnp.sum(cnt, axis=0), jnp.any(trunc, axis=0)

    return jax.jit(run)


# ----------------------------------------------------------------- build

def build(points, mesh, mask=None, *, axis: str = "data", phi: int = 32,
          kind: str = "spac", curve: str = "hilbert", bits: int = 16,
          coord_bits: int = 30, root_lo=None, root_hi=None, lam: int = 3,
          rounds: int = 5, capacity_rows: int | None = None,
          slack: float = 2.0, n_samples: int = 256) -> DistIndex:
    """points: (N, dim) sharded on dim 0 over `axis` (or host array —
    jax will split it; ragged N is padded to the shard count). Returns
    a DistIndex with one local-tree shard per device along `axis`.

    ``kind="spac"`` routes by curve code (``curve``/``bits``/
    ``coord_bits``); ``kind="porth"`` routes by sieve prefix key
    (``root_lo``/``root_hi`` domain tuples + ``lam``/``rounds``)."""
    n, dim = points.shape
    n_shards = mesh.shape[axis]
    points, mask = _pad_rows(jnp.asarray(points), mask, n_shards)
    n_local = n // max(n_shards, 1)
    cap = int(n_local * slack / n_shards) + 8
    if capacity_rows is None:
        capacity_rows = max(4 * ((n_shards * cap + phi - 1) // phi), 8)
    if kind == "porth":
        if root_lo is None or root_hi is None:
            raise ValueError("kind='porth' needs root_lo/root_hi")
        ckey = (("lam", int(lam)),
                ("root_hi", tuple(np.asarray(root_hi).tolist())),
                ("root_lo", tuple(np.asarray(root_lo).tolist())),
                ("rounds", int(rounds)))
    else:
        ckey = (("bits", int(bits)), ("coord_bits", int(coord_bits)),
                ("curve", curve))
    fn = _build_closure(mesh, axis, n_shards, cap, kind, phi,
                        int(capacity_rows), n_samples, ckey)
    tree, splitters, dropped = fn(points, mask)
    return DistIndex(tree=tree, splitters=splitters, dropped=dropped,
                     axis=axis, kind=kind, ckey=ckey)


# --------------------------------------------------------------- updates

def _update(index: DistIndex, pts, mask, mesh, op: str, slack: float):
    axis = index.axis
    n_shards = mesh.shape[axis]
    pts, mask = _pad_rows(jnp.asarray(pts), mask, n_shards)
    m = pts.shape[0]
    cap = int((m // n_shards) * slack / n_shards) + 8
    R = index.tree.pts.shape[-3]
    fn = _update_closure(mesh, axis, n_shards, cap, index.kind, op,
                         min(64, R), index.ckey)
    tree, dropped = fn(index.tree, pts, mask, index.splitters)
    return dataclasses.replace(index, tree=tree,
                               dropped=index.dropped + dropped)


def insert(index: DistIndex, pts, mesh, mask=None, *, slack: float = 2.0):
    return _update(index, pts, mask, mesh, "insert", slack)


def delete(index: DistIndex, pts, mesh, mask=None, *, slack: float = 2.0):
    return _update(index, pts, mask, mesh, "delete", slack)


# --------------------------------------------------------------- queries

def knn(index: DistIndex, qpts, k: int, mesh, chunk: int = 8,
        impl: str = "frontier", kernel: str = "auto"):
    """Exact distributed kNN. qpts: (Q, dim) replicated. Returns
    (d2 (Q, k) ascending, points (Q, k, dim), valid (Q, k)).

    ``impl="frontier"`` runs the chunked frontier traversal per shard;
    ``impl="pallas-frontier"`` the fused frontier kernel;
    ``impl="flat"`` the brute-force scan (``kernel`` picks the kernel
    flavor: auto/pallas/pallas-interpret/ref). ``mesh`` is accepted for
    API symmetry with the update path; the query program is
    shard-agnostic (vmap over the stacked axis — see the closure
    comment) so the arrays' own sharding drives the partitioning."""
    del mesh
    fn = _knn_closure(int(k), impl, kernel, int(chunk))
    return fn(index.tree, qpts)


def range_count(index: DistIndex, lo, hi, mesh, max_rows: int = 128):
    """Exact distributed range-count: per-shard count + global sum."""
    del mesh
    fn = _range_count_closure(int(max_rows))
    return fn(index.tree, lo, hi)


def size(index: DistIndex) -> jax.Array:
    t = index.tree
    return jnp.sum(jnp.where(t.active, t.count, 0))


def shard_sizes(index: DistIndex) -> jax.Array:
    """Per-shard live point counts, shape (n_shards,) — stacked-array
    arithmetic on metadata-addressable leaves (no shard_map launch), so
    cheap enough for per-shard obs gauges."""
    t = index.tree
    return jnp.sum(jnp.where(t.active, t.count, 0), axis=-1)
