"""Space-filling curves: Morton (Z) and Hilbert encodings, vectorized.

The paper (Sec. 2.2) uses 64-bit codes (32 bits/dim in 2D, 21 bits/dim in 3D).
JAX defaults to 32-bit; we default to uint32 codes (16 bits/dim in 2D, 10 in 3D)
and transparently use uint64 when ``bits * D > 32`` (requires JAX_ENABLE_X64).

The P-Orth tree never calls into this module (its selling point — Sec. 3 of the
paper); only the SPaC family and the Zd-tree baseline do.

Hilbert encoding follows Skilling, "Programming the Hilbert curve" (2004):
coordinates are transformed in-place into the "transpose" form, whose bit
interleave is the Hilbert index. All ops are vectorized over points; the loops
below run over *bit levels* (<= 32 unrolled iterations), not points.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "code_dtype",
    "morton_encode",
    "hilbert_encode",
    "hilbert_decode",
    "interleave_bits",
    "max_bits_for_dtype",
]


def code_dtype(dim: int, bits: int):
    """Smallest unsigned dtype that can hold a ``dim * bits``-bit code."""
    total = dim * bits
    if total <= 32:
        return jnp.uint32
    if total <= 64:
        return jnp.uint64
    raise ValueError(f"code of {total} bits does not fit a 64-bit word "
                     "(paper Sec. 3, 'Applicability': use the P-Orth tree)")


def max_bits_for_dtype(dim: int, dtype) -> int:
    width = jnp.dtype(dtype).itemsize * 8
    return width // dim


def _part1by1(x, dtype):
    """Spread bits of x so there is one zero bit between each (2D Morton)."""
    x = x.astype(dtype)
    if dtype == jnp.uint64:
        x &= jnp.uint64(0xFFFFFFFF)
        x = (x | (x << 16)) & jnp.uint64(0x0000FFFF0000FFFF)
        x = (x | (x << 8)) & jnp.uint64(0x00FF00FF00FF00FF)
        x = (x | (x << 4)) & jnp.uint64(0x0F0F0F0F0F0F0F0F)
        x = (x | (x << 2)) & jnp.uint64(0x3333333333333333)
        x = (x | (x << 1)) & jnp.uint64(0x5555555555555555)
    else:
        x &= jnp.uint32(0xFFFF)
        x = (x | (x << 8)) & jnp.uint32(0x00FF00FF)
        x = (x | (x << 4)) & jnp.uint32(0x0F0F0F0F)
        x = (x | (x << 2)) & jnp.uint32(0x33333333)
        x = (x | (x << 1)) & jnp.uint32(0x55555555)
    return x


def _part1by2(x, dtype):
    """Spread bits of x so there are two zero bits between each (3D Morton)."""
    x = x.astype(dtype)
    if dtype == jnp.uint64:
        x &= jnp.uint64(0x1FFFFF)  # 21 bits
        x = (x | (x << 32)) & jnp.uint64(0x1F00000000FFFF)
        x = (x | (x << 16)) & jnp.uint64(0x1F0000FF0000FF)
        x = (x | (x << 8)) & jnp.uint64(0x100F00F00F00F00F)
        x = (x | (x << 4)) & jnp.uint64(0x10C30C30C30C30C3)
        x = (x | (x << 2)) & jnp.uint64(0x1249249249249249)
    else:
        x &= jnp.uint32(0x3FF)  # 10 bits
        x = (x | (x << 16)) & jnp.uint32(0x030000FF)
        x = (x | (x << 8)) & jnp.uint32(0x0300F00F)
        x = (x | (x << 4)) & jnp.uint32(0x030C30C3)
        x = (x | (x << 2)) & jnp.uint32(0x09249249)
    return x


def interleave_bits(coords, bits: int):
    """Interleave integer coordinates (..., D) into a single SFC word.

    Bit ``j`` of ``coords[..., i]`` lands at position ``j * D + (D - 1 - i)``,
    i.e. coords[..., 0] provides the most-significant bit of each group —
    matching both the Morton convention and Skilling's transpose layout.
    """
    dim = coords.shape[-1]
    dtype = code_dtype(dim, bits)
    c = coords.astype(dtype)
    if dim == 2:
        return (_part1by1(c[..., 0], dtype) << 1) | _part1by1(c[..., 1], dtype)
    if dim == 3:
        return (
            (_part1by2(c[..., 0], dtype) << 2)
            | (_part1by2(c[..., 1], dtype) << 1)
            | _part1by2(c[..., 2], dtype)
        )
    # generic (D > 3): plain shift loop over bits.
    out = jnp.zeros(coords.shape[:-1], dtype)
    one = jnp.array(1, dtype)
    for b in range(bits):
        for i in range(dim):
            bit = (c[..., i] >> b) & one
            out = out | (bit << (b * dim + (dim - 1 - i)))
    return out


def morton_encode(coords, bits: int | None = None):
    """Morton (Z-curve) code of non-negative integer coordinates (..., D)."""
    dim = coords.shape[-1]
    if bits is None:
        bits = max_bits_for_dtype(dim, jnp.uint32)
    return interleave_bits(coords, bits)


def _axes_to_transpose(coords, bits: int):
    """Skilling's AxestoTranspose, vectorized over points.

    coords: (..., D) unsigned ints with values < 2**bits.
    Returns X (..., D) in 'transpose' form; interleaving X gives the Hilbert
    index.
    """
    dim = coords.shape[-1]
    dtype = code_dtype(dim, bits)
    X = [coords[..., i].astype(dtype) for i in range(dim)]
    M = np.uint64(1) << np.uint64(bits - 1)

    # Inverse undo
    Q = int(M)
    while Q > 1:
        P = jnp.array(Q - 1, dtype)
        Qc = jnp.array(Q, dtype)
        for i in range(dim):
            has = (X[i] & Qc) != 0
            # invert low bits of X[0], or exchange low bits of X[0] and X[i]
            t = jnp.where(has, jnp.zeros_like(X[0]), (X[0] ^ X[i]) & P)
            X0_inv = jnp.where(has, X[0] ^ P, X[0])
            X[0] = X0_inv ^ t
            if i != 0:
                X[i] = X[i] ^ t
        Q >>= 1

    # Gray encode
    for i in range(1, dim):
        X[i] = X[i] ^ X[i - 1]
    t = jnp.zeros_like(X[0])
    Q = int(M)
    while Q > 1:
        Qc = jnp.array(Q, dtype)
        t = jnp.where((X[dim - 1] & Qc) != 0, t ^ (Qc - 1), t)
        Q >>= 1
    for i in range(dim):
        X[i] = X[i] ^ t
    return jnp.stack(X, axis=-1)


def _transpose_to_axes(X, bits: int):
    """Skilling's TransposetoAxes (inverse of _axes_to_transpose)."""
    dim = X.shape[-1]
    dtype = code_dtype(dim, bits)
    X = [X[..., i].astype(dtype) for i in range(dim)]
    N = np.uint64(2) << np.uint64(bits - 1)

    # Gray decode by H ^ (H/2)
    t = X[dim - 1] >> 1
    for i in range(dim - 1, 0, -1):
        X[i] = X[i] ^ X[i - 1]
    X[0] = X[0] ^ t

    # Undo excess work
    Q = 2
    while Q != int(N):
        P = jnp.array(Q - 1, dtype)
        Qc = jnp.array(Q, dtype)
        for i in range(dim - 1, -1, -1):
            has = (X[i] & Qc) != 0
            t = jnp.where(has, jnp.zeros_like(X[0]), (X[0] ^ X[i]) & P)
            X0_inv = jnp.where(has, X[0] ^ P, X[0])
            X[0] = X0_inv ^ t
            if i != 0:
                X[i] = X[i] ^ t
        Q <<= 1
    return jnp.stack(X, axis=-1)


def hilbert_encode(coords, bits: int | None = None):
    """Hilbert code of non-negative integer coordinates (..., D)."""
    dim = coords.shape[-1]
    if bits is None:
        bits = max_bits_for_dtype(dim, jnp.uint32)
    X = _axes_to_transpose(coords, bits)
    return interleave_bits(X, bits)


def _deinterleave_bits(code, dim: int, bits: int):
    dtype = code_dtype(dim, bits)
    code = code.astype(dtype)
    one = jnp.array(1, dtype)
    outs = []
    for i in range(dim):
        x = jnp.zeros_like(code)
        for b in range(bits):
            bit = (code >> (b * dim + (dim - 1 - i))) & one
            x = x | (bit << b)
        outs.append(x)
    return jnp.stack(outs, axis=-1)


def hilbert_decode(code, dim: int, bits: int):
    """Inverse of hilbert_encode (used only by tests)."""
    X = _deinterleave_bits(code, dim, bits)
    return _transpose_to_axes(X, bits)
