"""Unified ``SpatialIndex`` facade over every tree family (the Index API).

The paper's central observation is that the P-Orth tree and the SPaC-tree
family share one operational contract — parallel batch build/insert/delete
plus exact kNN/range queries — and the comparison baselines (kd, Zd) fit the
same contract with rebuild-style updates. This module is that contract as
code: a string-keyed backend registry plus a thin immutable handle so callers
write

    idx = make_index("spac-h", points, phi=32)
    idx = idx.insert(batch)
    d2, ids = idx.knn(queries, k=10)

for any backend, local or distributed (pass ``mesh=``), and never touch
``capacity_rows``, ``overflowed``, ``grow`` or ``compact`` by hand.

Three guarantees the facade adds over the raw modules:

* **Automatic capacity.** Row capacity is sized by one shared heuristic
  (``capacity_for``); builds that overflow (or silently drop, for backends
  without an overflow flag) are retried at doubled capacity, and an insert
  that overflows triggers the transparent recovery ladder
  ``grow -> retry -> compact -> retry`` before giving up. Callers never see
  ``overflowed``.
* **Jit-cached update closures.** Insert/delete run through closures cached
  on ``(backend, batch shape, dtype, static params)`` — the ``ServeEngine``
  pattern — so a serving hot path that feeds fixed-shape batches never
  retraces. ``donate=True`` additionally donates the old tree's buffers to
  the update (serving mode: the caller must drop old handles after each
  update; the default keeps updates pure so benchmarks can re-time them).
* **One registry.** ``register_backend`` makes new tree families available
  to every benchmark, example and test loop that iterates ``BACKENDS``.

Registered kinds:

====== ===================================================================
kind   backend
====== ===================================================================
porth  P-Orth tree (sieve-built parallel orth-tree, paper Sec. 3)
spac-h SPaC-tree over the Hilbert curve (paper Sec. 4)
spac-z SPaC-tree over the Morton (Z-order) curve
spac-m alias of ``spac-z`` (Morton), kept for the paper's naming
cpam-h CPAM-like total-order ablation of spac-h (sorts touched rows)
cpam-z CPAM-like total-order ablation of spac-z
kd     parallel kd-tree baseline (object-median splits, rebuild updates)
zd     Zd-tree-like baseline (Morton presort, merge-rebuild updates)
====== ===================================================================
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .. import obs
from . import baselines, porth, queries, spac
from .engine import QueryEngine

# Default root domain for orth-style backends on integer coordinates —
# matches ``repro.data.points.DEFAULT_HI``. Pass ``root_lo``/``root_hi`` to
# ``make_index`` for data outside [0, 2^20)^D; float data defaults to the
# unit cube.
DEFAULT_ROOT_HI = 1 << 20


# ---------------------------------------------------------------------------
# capacity policy
# ---------------------------------------------------------------------------

def capacity_for(n_points: int, phi: int = 32, slack: int = 4) -> int:
    """Shared row-capacity heuristic: rows for ``n_points`` with ``slack``x
    headroom over the dense packing (leaves hold >= phi/2 points after a
    split, but cells can run underfull — orth backends use slack=8)."""
    return int(slack) * ((int(n_points) + phi - 1) // phi) + 64


def _round_capacity(rows: int) -> int:
    """Round up to a power of two so rebuild-style backends reuse their jit
    cache across nearby sizes instead of retracing every batch."""
    return 1 << max(int(rows) - 1, 15).bit_length()


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Backend:
    """Adapter spec every tree family registers.

    ``build(points, mask, *, phi, capacity_rows, **build_params) -> tree``;
    ``insert/delete(tree, pts, mask, **update_params) -> tree``. ``dynamic``
    backends update in place (fixed arrays + ``overflowed`` flag) and must
    provide ``grow``/``compact``; rebuild backends re-run ``build`` and take
    ``capacity_rows`` as an update param instead.
    """
    name: str
    build: Callable[..., Any]
    insert: Callable[..., Any]
    delete: Callable[..., Any]
    dynamic: bool
    grow: Callable[..., Any] | None = None
    compact: Callable[..., Any] | None = None
    cap_slack: int = 4
    build_params: tuple[str, ...] = ()
    insert_params: tuple[str, ...] = ()
    delete_params: tuple[str, ...] = ()
    defaults: dict[str, Any] = dataclasses.field(default_factory=dict)
    resolve: Callable[[dict, Any], dict] | None = None
    curve: str | None = None   # set for spac-family kinds (distributed)


BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend) -> None:
    """Add (or replace) a backend under ``backend.name``."""
    BACKENDS[backend.name] = backend


def get_backend(kind: str) -> Backend:
    try:
        return BACKENDS[kind]
    except KeyError:
        raise KeyError(
            f"unknown index kind {kind!r}; registered: "
            f"{sorted(BACKENDS)}") from None


# ---------------------------------------------------------------------------
# per-family adapters
# ---------------------------------------------------------------------------

def _porth_resolve(params: dict, points) -> dict:
    dim = points.shape[1]
    out = dict(params)
    if out.get("lam") is None:
        out["lam"] = 3 if dim == 2 else 2   # paper: 3 levels/round in 2D
    if jnp.issubdtype(points.dtype, jnp.floating):
        lo, hi = 0.0, 1.0
    else:
        lo, hi = 0, DEFAULT_ROOT_HI
    if out.get("root_lo") is None:
        out["root_lo"] = jnp.full((dim,), lo, points.dtype)
    if out.get("root_hi") is None:
        out["root_hi"] = jnp.full((dim,), hi, points.dtype)
    out["root_lo"] = jnp.asarray(out["root_lo"], points.dtype)
    out["root_hi"] = jnp.asarray(out["root_hi"], points.dtype)
    return out


def _porth_build(points, mask, *, phi, capacity_rows, root_lo, root_hi,
                 lam, rounds):
    return porth.build(points, root_lo, root_hi, mask, phi=phi, lam=lam,
                       rounds=rounds, capacity_rows=capacity_rows)


def _porth_insert(tree, pts, mask, *, max_overflow_rows):
    mor = min(int(max_overflow_rows), tree.pts.shape[0])
    return porth.insert(tree, pts, mask, max_overflow_rows=mor)


def _porth_delete(tree, pts, mask):
    return porth.delete(tree, pts, mask)


def _spac_build(points, mask, *, phi, capacity_rows, curve, bits,
                coord_bits):
    return spac.build(points, mask, phi=phi, curve=curve, bits=bits,
                      coord_bits=coord_bits, capacity_rows=capacity_rows)


def _spac_insert(tree, pts, mask, *, max_overflow_rows, sort_rows):
    mor = min(int(max_overflow_rows), tree.pts.shape[0])
    return spac.insert(tree, pts, mask, max_overflow_rows=mor,
                       sort_rows=sort_rows)


def _spac_delete(tree, pts, mask):
    return spac.delete(tree, pts, mask)


def _kd_build(points, mask, *, phi, capacity_rows, max_depth):
    return baselines.kd_build(points, mask, phi=phi, max_depth=max_depth,
                              capacity_rows=capacity_rows)


def _kd_insert(index, pts, mask, *, capacity_rows, max_depth):
    return baselines.kd_insert(index, pts, mask, max_depth=max_depth,
                               capacity_rows=capacity_rows)


def _kd_delete(index, pts, mask, *, capacity_rows, max_depth):
    return baselines.kd_delete(index, pts, mask, max_depth=max_depth,
                               capacity_rows=capacity_rows)


def _zd_build(points, mask, *, phi, capacity_rows, bits, coord_bits, lam):
    return baselines.zd_build(points, mask, phi=phi, bits=bits,
                              coord_bits=coord_bits, lam=lam,
                              capacity_rows=capacity_rows)


def _zd_insert(index, pts, mask, *, capacity_rows, bits, coord_bits, lam):
    return baselines.zd_insert(index, pts, mask, bits=bits,
                               coord_bits=coord_bits, lam=lam,
                               capacity_rows=capacity_rows)


def _zd_delete(index, pts, mask, *, capacity_rows, bits, coord_bits, lam):
    return baselines.zd_delete(index, pts, mask, bits=bits,
                               coord_bits=coord_bits, lam=lam,
                               capacity_rows=capacity_rows)


register_backend(Backend(
    name="porth", build=_porth_build, insert=_porth_insert,
    delete=_porth_delete, dynamic=True, grow=porth.grow,
    compact=porth.compact, cap_slack=8,
    build_params=("root_lo", "root_hi", "lam", "rounds"),
    insert_params=("max_overflow_rows",),
    defaults=dict(root_lo=None, root_hi=None, lam=None, rounds=5,
                  max_overflow_rows=64),
    resolve=_porth_resolve))

for _name, _curve, _sort in (("spac-h", "hilbert", False),
                             ("spac-z", "morton", False),
                             ("spac-m", "morton", False),
                             ("cpam-h", "hilbert", True),
                             ("cpam-z", "morton", True)):
    register_backend(Backend(
        name=_name, build=_spac_build, insert=_spac_insert,
        delete=_spac_delete, dynamic=True, grow=spac.grow,
        compact=spac.compact, cap_slack=4,
        build_params=("curve", "bits", "coord_bits"),
        insert_params=("max_overflow_rows", "sort_rows"),
        defaults=dict(curve=_curve, bits=16, coord_bits=30,
                      max_overflow_rows=64, sort_rows=_sort),
        curve=_curve))

register_backend(Backend(
    name="kd", build=_kd_build, insert=_kd_insert, delete=_kd_delete,
    dynamic=False, cap_slack=4,
    build_params=("max_depth",),
    insert_params=("max_depth",), delete_params=("max_depth",),
    defaults=dict(max_depth=24)))

register_backend(Backend(
    name="zd", build=_zd_build, insert=_zd_insert, delete=_zd_delete,
    dynamic=False, cap_slack=8,
    build_params=("bits", "coord_bits", "lam"),
    insert_params=("bits", "coord_bits", "lam"),
    delete_params=("bits", "coord_bits", "lam"),
    defaults=dict(bits=15, coord_bits=20, lam=3)))


# ---------------------------------------------------------------------------
# jit-cached update closures (ServeEngine pattern)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _update_closure(kind: str, op: str, m: int, dim: int, dtype: str,
                    pkey: tuple, donate: bool):
    """One jitted closure per (backend, batch shape, dtype, static params).

    Tree shapes are handled by jax's own trace cache inside the closure, so
    a fixed-shape update stream compiles exactly once. ``donate`` releases
    the old tree's buffers to the update (serving mode)."""
    obs.count("index.update_plan_miss")
    backend = get_backend(kind)
    fn = backend.insert if op == "insert" else backend.delete
    kw = dict(pkey)

    def run(tree, pts, mask):
        return fn(tree, pts, mask, **kw)

    return jax.jit(run, donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------

class SpatialIndex:
    """Immutable handle over one backend tree; updates return new handles.

    Construct via :func:`make_index`. All query methods delegate to the
    :class:`repro.core.engine.QueryEngine` through the backend's
    ``LeafView``: exact by default (no ``max_rows``/``cap``/``truncated``
    on this surface), jit-cached plans, ``impl="auto"`` kernel routing.
    """

    def __init__(self, kind: str, tree, *, phi: int, params: dict,
                 donate: bool = False, size_hint: int = 0,
                 rebuild_rows: int = 0, engine: QueryEngine | None = None):
        self.kind = kind
        self._backend = get_backend(kind)
        self._tree = tree
        self.phi = phi
        self._params = params
        self._donate = donate
        # host-side upper bound on live points (rebuild backends size their
        # next rebuild from it without a device sync; never decremented so
        # capacity stays sufficient)
        self._size_hint = size_hint
        self._rebuild_rows = rebuild_rows
        # planning state (flat-scan budget, converged query buffers)
        # rides along across functional updates
        self._engine = engine if engine is not None else QueryEngine()

    # -- plumbing ----------------------------------------------------------

    def _wrap(self, tree, size_hint=None, rebuild_rows=None) -> \
            "SpatialIndex":
        out = SpatialIndex.__new__(SpatialIndex)
        out.kind = self.kind
        out._backend = self._backend
        out._tree = tree
        out.phi = self.phi
        out._params = self._params
        out._donate = self._donate
        out._size_hint = (self._size_hint if size_hint is None
                          else size_hint)
        out._rebuild_rows = (self._rebuild_rows if rebuild_rows is None
                             else rebuild_rows)
        out._engine = self._engine
        return out

    def _prep(self, pts, mask):
        pts = jnp.asarray(pts)
        if mask is None:
            mask = jnp.ones(pts.shape[0], bool)
        else:
            mask = jnp.asarray(mask, bool)
        return pts, mask

    def _static_kwargs(self, op: str, extra: dict | None = None) -> tuple:
        names = (self._backend.insert_params if op == "insert"
                 else self._backend.delete_params)
        kw = {k: self._params[k] for k in names}
        if extra:
            kw.update(extra)
        return tuple(sorted(kw.items()))

    def _run_update(self, op: str, tree, pts, mask,
                    extra: dict | None = None):
        # donation is a no-op on CPU and only triggers "unusable donated
        # buffer" warnings there — gate it to real accelerators
        donate = self._donate and jax.default_backend() != "cpu"
        fn = _update_closure(self.kind, op, pts.shape[0], pts.shape[1],
                             str(pts.dtype), self._static_kwargs(op, extra),
                             donate)
        # compile-cost attribution (no-op unless a capture_costs recorder
        # is installed): charge this update plan's flops/bytes once per
        # signature, next to the update_plan_miss it corresponds to
        obs.costs.capture(
            fn, (tree, pts, mask),
            f"update.{self.kind}.{op}.m{pts.shape[0]}.d{pts.shape[1]}"
            f".r{tree.pts.shape[0]}")
        return fn(tree, pts, mask)

    # -- introspection -----------------------------------------------------

    @property
    def tree(self):
        """The raw backend pytree (escape hatch; prefer the facade)."""
        return self._tree

    @property
    def capacity_rows(self) -> int:
        """Allocated leaf-row capacity (grows automatically)."""
        return self._tree.pts.shape[0]

    @property
    def num_rows(self):
        """Occupied leaf rows (device scalar; ``int()`` it to sync)."""
        return jnp.sum(self._tree.active, dtype=jnp.int32)

    @property
    def dim(self) -> int:
        return self._tree.pts.shape[2]

    @property
    def size(self):
        """Live point count (device scalar; ``int()`` it to sync)."""
        return self._tree.size

    @property
    def nbytes(self) -> int:
        """Resident bytes of the backend tree's buffers — pure
        shape/dtype arithmetic (``repro.obs.memory.tree_bytes``), never
        a device read, so safe on dispatch paths."""
        return obs.tree_bytes(self._tree)

    def __len__(self) -> int:
        return int(self.size)

    def view(self) -> queries.LeafView:
        return self._tree.view()

    def block_until_ready(self) -> "SpatialIndex":
        """Wait for all device work on the tree (duck-types with
        ``jax.block_until_ready`` so timing harnesses see real latency)."""
        jax.block_until_ready(self._tree)
        return self

    def extract_points(self):
        """All (points, valid) pairs flattened — for rebuilds/export."""
        R, C, dim = self._tree.pts.shape
        ok = (self._tree.valid & self._tree.active[:, None]).reshape(R * C)
        return self._tree.pts.reshape(R * C, dim), ok

    # -- updates -----------------------------------------------------------

    def insert(self, new_pts, new_mask=None) -> "SpatialIndex":
        """Batch insert; auto-grows on overflow, so the result never has
        ``overflowed`` set."""
        pts, mask = self._prep(new_pts, new_mask)
        m = pts.shape[0]
        if not self._backend.dynamic:
            hint = self._size_hint + m
            rows = max(self._rebuild_rows, _round_capacity(
                capacity_for(hint, self.phi, self._backend.cap_slack)))
            # rebuild backends drop silently past row capacity (no
            # overflow flag), so verify the rebuilt size and retry bigger
            # — clustered data can need far more rows than the heuristic
            expected = int(self._tree.size) + int(jnp.sum(mask))
            for _ in range(6):
                tree = self._run_update("insert", self._tree, pts, mask,
                                        extra=dict(capacity_rows=rows))
                if int(tree.size) == expected:
                    break
                obs.count("index.rebuild_retry")
                rows = 2 * rows
            else:
                raise RuntimeError(
                    f"{self.kind}: insert of {m} points still overflows "
                    f"at capacity_rows={rows}")
            return self._wrap(tree, size_hint=hint, rebuild_rows=rows)
        tree = self._run_update("insert", self._tree, pts, mask)
        if bool(tree.overflowed):
            tree = self._recover_insert(tree, pts, mask)
        return self._wrap(tree)

    def _recover_insert(self, failed_tree, pts, mask):
        """The grow -> retry -> compact -> retry ladder (all-or-nothing
        inserts return the old contents with ``overflowed`` set, so the
        failed tree is a valid starting point even under donation)."""
        b = self._backend
        tree = dataclasses.replace(failed_tree,
                                   overflowed=jnp.asarray(False))
        live = int(tree.size) + pts.shape[0]
        need = _round_capacity(capacity_for(live, self.phi, b.cap_slack))
        mor = int(self._params.get("max_overflow_rows", 64))
        recovery = obs.span("index.recover_insert", kind=self.kind).begin()
        for attempt in range(4):
            cap = max(need << attempt, 2 * tree.pts.shape[0])
            obs.count("index.grow" if attempt == 0 else "index.compact")
            tree = (b.grow(tree, cap) if attempt == 0
                    else b.compact(tree, cap))
            mor = min(4 * mor, cap)
            out = self._run_update("insert", tree, pts, mask,
                                   extra=dict(max_overflow_rows=mor))
            if not bool(out.overflowed):
                recovery.set(attempts=attempt + 1, capacity_rows=cap).end()
                return out
            tree = dataclasses.replace(out, overflowed=jnp.asarray(False))
        recovery.set(failed=True).end()
        raise RuntimeError(
            f"{self.kind}: insert of {pts.shape[0]} points still overflows "
            f"at capacity_rows={cap}")

    def insert_unchecked(self, new_pts, new_mask=None) -> "SpatialIndex":
        """Dispatch-only insert for the serving runtime: skips the
        host-side ``overflowed`` read (a full device sync), so the call
        returns as soon as the jit-cached update closure is enqueued and
        queries against *older* versions can overlap with it on device.

        The returned handle may carry a sticky ``overflowed`` flag; the
        caller owns checking it at its next sync point —
        :class:`repro.serving.SpatialServer` defers the check to
        ``commit()`` and replays from the last good version on overflow.
        Rebuild-style backends (kd/zd) fall back to the checked
        :meth:`insert` (their size verification is inherently
        synchronous)."""
        if not self._backend.dynamic:
            return self.insert(new_pts, new_mask)
        pts, mask = self._prep(new_pts, new_mask)
        return self._wrap(self._run_update("insert", self._tree, pts,
                                           mask))

    def delete(self, del_pts, del_mask=None) -> "SpatialIndex":
        """Batch delete (exact multiset semantics; absent points no-op)."""
        pts, mask = self._prep(del_pts, del_mask)
        if not self._backend.dynamic:
            # removal can only shrink groups, never split them, so the
            # rebuild always fits at the current capacity
            rows = max(self._rebuild_rows, self.capacity_rows)
            tree = self._run_update("delete", self._tree, pts, mask,
                                    extra=dict(capacity_rows=rows))
            return self._wrap(tree, rebuild_rows=rows)
        return self._wrap(self._run_update("delete", self._tree, pts, mask))

    def delete_unchecked(self, del_pts, del_mask=None) -> "SpatialIndex":
        """Dispatch-only delete for the serving runtime — the async
        counterpart of :meth:`insert_unchecked`. Deletes cannot overflow
        rows, so for dynamic backends this is :meth:`delete` itself; the
        alias exists so the server can dispatch every update through the
        same ``*_unchecked`` spelling regardless of direction."""
        return self.delete(del_pts, del_mask)

    # -- queries (exact by default; see repro.core.engine) -----------------

    @property
    def engine(self) -> QueryEngine:
        """The query planner riding along with this index (flat-scan
        budget, converged buffer buckets)."""
        return self._engine

    def knn(self, qpts, k: int, *, impl: str = "auto"):
        """Exact batched kNN -> (d2 (Q, k) ascending, flat ids (Q, k)).

        ``impl``: "auto" (planner routes to the Pallas brute-force
        kernel or the fused frontier kernel), or a forced spelling —
        "frontier", "pallas-frontier", "pallas-frontier-interpret",
        "flat", "pallas", "pallas-interpret", "ref"."""
        return self._engine.knn(self.view(), jnp.asarray(qpts), k,
                                impl=impl)

    def knn_points(self, qpts, k: int, *, impl: str = "auto"):
        """kNN returning coordinates: (d2, neighbor points, valid)."""
        view = self.view()
        d2, ids = self._engine.knn(view, jnp.asarray(qpts), k, impl=impl)
        return d2, queries.gather_points(view, ids), ids >= 0

    def range_count(self, lo, hi):
        """Exact batched range count -> counts (Q,). No sizing knobs:
        the engine escalates its row buffer until nothing truncates."""
        return self._engine.range_count(self.view(), jnp.asarray(lo),
                                        jnp.asarray(hi))

    def range_list(self, lo, hi):
        """Exact batched range report -> (ids (Q, cap) padded with -1,
        counts (Q,)); cap is auto-sized so every hit is present."""
        return self._engine.range_list(self.view(), jnp.asarray(lo),
                                       jnp.asarray(hi))

    def __repr__(self):
        return (f"SpatialIndex(kind={self.kind!r}, "
                f"capacity_rows={self.capacity_rows}, phi={self.phi})")


# ---------------------------------------------------------------------------
# constructor
# ---------------------------------------------------------------------------

def make_index(kind: str, points, mask=None, *, phi: int = 32,
               capacity_rows: int | None = None,
               capacity_points: int | None = None, mesh=None,
               donate: bool = False, **params):
    """Build an index of the given registered ``kind`` over ``points``.

    ``capacity_points`` sizes row capacity for the *maximum* live points
    expected over the index's lifetime (defaults to ``len(points)``);
    ``capacity_rows`` overrides the heuristic outright. Backend-specific
    options (``curve``, ``bits``, ``root_lo``, ``lam``, ...) pass through as
    keyword params. With ``mesh=`` the index is built key-range-partitioned
    over the mesh's devices and a :class:`DistributedIndex` is returned
    (mesh-capable kinds: the spac family routes by curve code, porth by
    sieve prefix key).
    """
    if mesh is not None:
        if donate:
            raise ValueError("donate=True is not supported for "
                             "distributed indexes")
        return DistributedIndex.build(kind, points, mesh, mask=mask,
                                      phi=phi, capacity_rows=capacity_rows,
                                      capacity_points=capacity_points,
                                      **params)
    backend = get_backend(kind)
    pts = jnp.asarray(points)
    n = pts.shape[0]
    resolved = dict(backend.defaults)
    unknown = set(params) - set(resolved)
    if unknown:
        raise TypeError(f"{kind}: unknown params {sorted(unknown)}; "
                        f"accepted: {sorted(resolved)}")
    resolved.update(params)
    if backend.resolve is not None:
        resolved = backend.resolve(resolved, pts)

    pts_mask = (jnp.ones(n, bool) if mask is None
                else jnp.asarray(mask, bool))
    expected = n if mask is None else int(jnp.sum(pts_mask))
    cap = capacity_rows if capacity_rows is not None else capacity_for(
        capacity_points if capacity_points is not None else n,
        phi, backend.cap_slack)
    build_kw = {k: resolved[k] for k in backend.build_params}
    for _ in range(8):
        tree = backend.build(pts, pts_mask, phi=phi, capacity_rows=cap,
                             **build_kw)
        # backends without an overflow flag drop silently; the size check
        # catches both
        short = (bool(getattr(tree, "overflowed", False))
                 or int(tree.size) != expected)
        if not short:
            break
        obs.count("index.build_retry")
        # jump at least to the heuristic (explicit caps can be tiny), then
        # keep doubling
        cap = max(2 * cap,
                  capacity_for(expected, phi, backend.cap_slack))
    else:
        raise RuntimeError(f"{kind}: build of {expected} points overflows "
                           f"even at capacity_rows={cap}")
    return SpatialIndex(kind, tree, phi=phi, params=resolved, donate=donate,
                        size_hint=expected,
                        rebuild_rows=0 if backend.dynamic else cap)


# ---------------------------------------------------------------------------
# distributed adapter
# ---------------------------------------------------------------------------

class DistributedIndex:
    """The same surface over an SFC-range-partitioned index on a device
    mesh (:mod:`repro.core.distributed`). kNN returns neighbor coordinates
    instead of flat slot ids (ids are shard-local and meaningless
    globally); ``range_list`` is not offered distributed."""

    def __init__(self, kind: str, index, mesh, *, phi: int,
                 slack: float = 2.0, build_kw: dict | None = None,
                 engine: QueryEngine | None = None):
        self.kind = kind
        self._index = index
        self.mesh = mesh
        self.phi = phi
        self.slack = slack
        # everything needed to re-shard at a larger capacity (overflow
        # recovery keeps the facade's never-see-overflowed contract)
        self._build_kw = build_kw or {}
        self._engine = engine if engine is not None else QueryEngine()

    @classmethod
    def build(cls, kind: str, points, mesh, *, mask=None, phi: int = 32,
              capacity_rows: int | None = None,
              capacity_points: int | None = None, slack: float = 2.0,
              n_samples: int = 256, axis: str = "data", **params):
        from . import distributed as D
        backend = get_backend(kind)
        pts = jnp.asarray(points)
        if kind == "porth":
            # the sieve routes by its own prefix keys (Morton codes from
            # midpoint comparisons), so float domains shard exactly
            allowed = ("root_lo", "root_hi", "lam", "rounds")
            resolved = {k: params.pop(k, backend.defaults[k])
                        for k in allowed}
            if params:
                raise TypeError(f"{kind} (distributed): unknown params "
                                f"{sorted(params)}")
            resolved = _porth_resolve(resolved, pts)
            import numpy as np
            route_kw = dict(
                kind="porth",
                root_lo=tuple(np.asarray(resolved["root_lo"]).tolist()),
                root_hi=tuple(np.asarray(resolved["root_hi"]).tolist()),
                lam=int(resolved["lam"]), rounds=int(resolved["rounds"]))
        elif backend.curve is not None and \
                not backend.defaults.get("sort_rows"):
            bits = params.pop("bits", backend.defaults["bits"])
            coord_bits = params.pop("coord_bits",
                                    backend.defaults["coord_bits"])
            if params:
                raise TypeError(f"{kind} (distributed): unknown params "
                                f"{sorted(params)}")
            route_kw = dict(kind="spac", curve=backend.curve, bits=bits,
                            coord_bits=coord_bits)
        else:
            raise ValueError(
                f"distributed indexes require a mesh-capable kind "
                f"(spac-family or porth), got {kind!r}")
        if capacity_rows is None and capacity_points is not None:
            # per-shard rows for the lifetime maximum, with 2x headroom
            # for routing imbalance
            n_shards = mesh.shape[axis]
            capacity_rows = capacity_for(
                2 * capacity_points // max(n_shards, 1), phi,
                backend.cap_slack)
        build_kw = dict(axis=axis, phi=phi, capacity_rows=capacity_rows,
                        slack=slack, n_samples=n_samples, **route_kw)
        expected = pts.shape[0] if mask is None else int(
            jnp.sum(jnp.asarray(mask, bool)))
        for _ in range(6):
            idx = D.build(pts, mesh, mask, **build_kw)
            # two silent-loss modes: shard-local builds drop past row
            # capacity, and skewed routing overflows the all_to_all slab
            # (reported via `dropped`) — escalate whichever bit
            size, dropped = int(D.size(idx)), int(idx.dropped)
            if size == expected:
                break
            if dropped:
                build_kw["slack"] = 2 * build_kw["slack"]
            if size + dropped != expected:
                build_kw["capacity_rows"] = 2 * idx.tree.pts.shape[-3]
        else:
            raise RuntimeError(
                f"{kind} (distributed): build of {expected} points still "
                f"loses points at capacity_rows="
                f"{build_kw['capacity_rows']}, slack={build_kw['slack']}")
        return cls(kind, idx, mesh, phi=phi, slack=build_kw["slack"],
                   build_kw=build_kw)

    def _wrap(self, idx) -> "DistributedIndex":
        return DistributedIndex(self.kind, idx, self.mesh, phi=self.phi,
                                slack=self.slack, build_kw=self._build_kw,
                                engine=self._engine)

    @property
    def index(self):
        """The raw :class:`repro.core.distributed.DistIndex`."""
        return self._index

    @property
    def size(self):
        from . import distributed as D
        return D.size(self._index)

    def __len__(self) -> int:
        return int(self.size)

    @property
    def dropped(self):
        """Points lost to routing-slab overflow (0 = exact; re-shard with a
        larger ``slack`` if nonzero)."""
        return self._index.dropped

    @property
    def tree(self):
        """The stacked (n_shards, ...) backend pytree — the same handle
        the serving runtime uses for memory accounting and barriers on
        local indexes. Note ``overflowed`` is a stacked per-shard vector
        here; reduce with ``jnp.any`` before branching on it."""
        return self._index.tree

    def shard_sizes(self):
        """Per-shard live point counts, shape (n_shards,) — metadata
        arithmetic on the stacked leaves, cheap enough for per-shard
        obs gauges in the serving driver."""
        from . import distributed as D
        return D.shard_sizes(self._index)

    @property
    def nbytes(self) -> int:
        """Resident bytes across all shards (metadata arithmetic —
        global arrays report their full logical footprint)."""
        return obs.tree_bytes(self._index)

    def insert(self, pts, mask=None) -> "DistributedIndex":
        """Batch insert. Two shard-level failure modes are recovered
        here so (as with the local facade) callers never lose points: a
        shard whose rows fill up keeps its old contents and raises
        ``overflowed`` (all-or-nothing), and a skewed batch can overflow
        the fixed all_to_all routing slab (``dropped`` grows). Either
        way we re-shard the pre-insert snapshot plus the batch at
        doubled per-shard capacity / escalated slack."""
        from . import distributed as D
        pts = jnp.asarray(pts)
        base = int(self._index.dropped)
        slack = self.slack
        for _ in range(3):
            out = D.insert(self._index, pts, self.mesh, mask, slack=slack)
            if bool(jnp.any(out.tree.overflowed)):
                break               # shard rows full: re-shard below
            if int(out.dropped) == base:
                res = self._wrap(out)
                res.slack = slack   # keep the slack that worked
                return res
            # routing slab too tight: a fully-skewed batch (all entries
            # to one shard) needs slack ~ n_shards, so jump there
            slack = max(2 * slack,
                        self.mesh.shape[self._build_kw["axis"]])
        old_pts, old_ok = self.extract_points()
        m = pts.shape[0]
        batch_ok = jnp.ones(m, bool) if mask is None else jnp.asarray(
            mask, bool)
        all_pts = jnp.concatenate([old_pts, pts.astype(old_pts.dtype)])
        all_ok = jnp.concatenate([old_ok, batch_ok])
        # shard_map needs the leading dim divisible by the shard count
        kw = self._build_kw
        n_shards = self.mesh.shape[kw["axis"]]
        pad = (-all_pts.shape[0]) % n_shards
        if pad:
            all_pts = jnp.concatenate(
                [all_pts, jnp.zeros((pad, all_pts.shape[1]),
                                    all_pts.dtype)])
            all_ok = jnp.concatenate([all_ok, jnp.zeros(pad, bool)])
        # the classmethod retries at doubling capacity until the full
        # multiset fits; routing-key params pass through per kind
        extra = {k: kw[k] for k in ("bits", "coord_bits", "root_lo",
                                    "root_hi", "lam", "rounds") if k in kw}
        return DistributedIndex.build(
            self.kind, all_pts, self.mesh, mask=all_ok, phi=self.phi,
            capacity_rows=2 * self._index.tree.pts.shape[-3],
            slack=slack, n_samples=kw["n_samples"], axis=kw["axis"],
            **extra)

    def insert_unchecked(self, pts, mask=None) -> "DistributedIndex":
        """Dispatch-only insert for the serving runtime: no host-side
        reads of ``dropped`` or the per-shard ``overflowed`` flags, so
        the call returns once the cached shard_map program is enqueued
        and queries against older versions overlap with it on device.

        Both failure signals are sticky (``overflowed`` per shard in the
        stacked tree, ``dropped`` accumulated on the DistIndex) — the
        caller owns checking them at its next sync point;
        :class:`repro.serving.SpatialServer` defers both to ``commit()``
        and replays from the last good version."""
        from . import distributed as D
        out = D.insert(self._index, jnp.asarray(pts), self.mesh, mask,
                       slack=self.slack)
        return self._wrap(out)

    def delete_unchecked(self, pts, mask=None) -> "DistributedIndex":
        """Dispatch-only delete: like :meth:`insert_unchecked`, skips the
        host-side ``dropped`` check (a dropped delete entry means a point
        that should have died survives — caught at commit)."""
        from . import distributed as D
        out = D.delete(self._index, jnp.asarray(pts), self.mesh, mask,
                       slack=self.slack)
        return self._wrap(out)

    def delete(self, pts, mask=None) -> "DistributedIndex":
        """Batch delete. A skewed batch can overflow the routing slab, in
        which case the overflowed entries would silently never be deleted
        — retry from the (functional, untouched) pre-delete index with
        escalated slack until nothing is dropped."""
        from . import distributed as D
        pts = jnp.asarray(pts)
        base = int(self._index.dropped)
        slack = self.slack
        for _ in range(5):
            out = D.delete(self._index, pts, self.mesh, mask, slack=slack)
            if int(out.dropped) == base:
                res = self._wrap(out)
                res.slack = slack   # keep the slack that worked
                return res
            # worst case (fully-skewed batch) needs slack ~ n_shards
            slack = max(2 * slack,
                        self.mesh.shape[self._build_kw["axis"]])
        raise RuntimeError(
            f"{self.kind} (distributed): delete batch still overflows "
            f"the routing slab at slack={slack}")

    @property
    def engine(self) -> QueryEngine:
        return self._engine

    def knn(self, qpts, k: int, *, impl: str = "auto"):
        """Exact distributed kNN -> (d2, neighbor points, valid): the
        engine routes each shard's local query (frontier vs flat scan)
        and merges via top-k of per-shard top-k."""
        return self._engine.knn_dist(self._index, jnp.asarray(qpts), k,
                                     self.mesh, impl=impl)

    knn_points = knn

    def range_count(self, lo, hi):
        """Exact distributed range count -> counts (Q,): per-shard
        counts + psum, row buffers escalated until no shard truncates."""
        return self._engine.range_count_dist(
            self._index, jnp.asarray(lo), jnp.asarray(hi), self.mesh)

    def block_until_ready(self) -> "DistributedIndex":
        jax.block_until_ready(self._index)
        return self

    def extract_points(self):
        t = self._index.tree
        dim = t.pts.shape[-1]
        ok = (t.valid & t.active[..., None]).reshape(-1)
        return t.pts.reshape(-1, dim), ok

    def __repr__(self):
        return (f"DistributedIndex(kind={self.kind!r}, "
                f"mesh={dict(self.mesh.shape)}, phi={self.phi})")
