"""Unified ``QueryEngine``: exact-by-default queries with auto-sized
buffers, jit-cached plans, and Pallas kernel routing (the Query API).

PR 1 gave *updates* one facade; this module does the same for *queries*.
The raw engines in :mod:`repro.core.queries` are exact only when the
caller sizes their fixed-capacity buffers correctly (``max_rows`` rows
gathered per range query, ``cap`` output slots per range-list) and
checks the ``truncated`` flags — a contract benchmarks and servers
silently violated. The :class:`QueryEngine` owns those knobs instead:

* **Exact by default.** Results are checked on device and the engine
  escalates ``max_rows``/``cap`` through power-of-two buckets
  (mirroring ``index._round_capacity``) and re-runs until nothing is
  truncated. A query stream therefore retraces at most O(log R) times
  per (query kind, batch shape); the converged bucket is remembered per
  engine so steady-state workloads never escalate again.
* **Jit-cached plans.** Every query runs through a closure cached on
  ``(op, Q-shape, dtype, k/caps, impl)`` — exactly like the facade's
  ``_update_closure`` — so fixed workloads compile once. The module
  counts closure traces (:func:`trace_count`) so tests can assert the
  retrace bound.
* **Execution planner.** ``impl="auto"`` routes kNN to the Pallas
  brute-force kernel (:mod:`repro.kernels.knn`) when the index's slot
  count ``R*C`` fits a flat-scan budget (small indexes, post-compact
  trees) and to the fused frontier kernel
  (:mod:`repro.kernels.frontier`) otherwise — pruned traversal with
  the running top-k on-chip, compensated (centered) MXU distances for
  selection, and a direct ``|q - p|^2`` rescore of the k hits, so the
  returned distances match the chunked traversal at any coordinate
  magnitude. Forced
  spellings: ``"frontier"`` (chunked host-orchestrated traversal,
  ``chunk`` auto-picked from R), ``"pallas-frontier"``,
  ``"pallas-frontier-interpret"``, ``"flat"`` (brute force, kernel
  auto), ``"pallas"``, ``"pallas-interpret"``, ``"ref"``.
* **Distributed.** The same engine fronts
  :class:`repro.core.index.DistributedIndex`: per-shard queries run the
  unjitted ``*_impl`` spellings inside shard_map (required — see the
  ROADMAP miscompile note), the shard-merge step takes the top-k of
  per-shard top-k (kNN) or the psum of per-shard counts (range), and
  the same bucket escalation wraps the whole exchange.

kNN results are *canonical*: each query's k hits are sorted by
``(d2, id)``, so any two exact impls return bit-identical output on
tie-free data (asserted across backends in tests/test_queries_parity.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import obs
from ..kernels.frontier import ops as frontier_ops
from ..kernels.knn import ops as knn_ops
from . import queries
from .leafstore import BIG

# happy-path starting buckets; the engine escalates from here and
# remembers where it converged, so these only shape the first call
DEFAULT_MAX_ROWS = 128
DEFAULT_CAP = 512
# slot count (R*C) below which a flat brute-force scan beats the
# frontier traversal's sort + while_loop (the whole index fits a few
# MXU tiles); above it the bbox pruning wins
DEFAULT_FLAT_BUDGET = 1 << 15

KNN_IMPLS = ("auto", "frontier", "pallas-frontier",
             "pallas-frontier-interpret", "flat", "pallas",
             "pallas-interpret", "ref")

_STATS = {"traces": 0}


def trace_count() -> int:
    """Total query-closure traces this process (compilations, not calls);
    tests assert the O(log R) escalation bound against it."""
    return _STATS["traces"]


def reset_trace_count() -> None:
    _STATS["traces"] = 0


def _pow2(x: int) -> int:
    """Smallest power of two >= x (>= 1)."""
    return 1 << max(int(x) - 1, 0).bit_length()


def auto_chunk(rows: int) -> int:
    """Frontier chunk width from row count: ~R/16 rows per while-loop
    step, power of two, clamped to [8, 128]. Small indexes stop early
    on fine-grained bounds; large ones amortize the loop overhead."""
    return min(128, max(8, _pow2(rows // 16)))


def canonical_knn(d2, ids):
    """Sort each query's k hits by (d2, id) and re-pad invalid slots.

    Makes exact impls comparable bit-for-bit: top-k merge order differs
    between the frontier traversal and the flat scan, so without a
    canonical order equal-distance hits could legally permute."""
    d2, ids = jax.lax.sort((d2, ids), dimension=-1, num_keys=2)
    return d2, jnp.where(d2 >= BIG, -1, ids)


# ---------------------------------------------------------------------------
# jit-cached query closures (the _update_closure pattern)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _knn_closure(q: int, dim: int, dtype: str, k: int, route: str,
                 param):
    """One jitted closure per (Q-shape, dtype, k, route, chunk|kernel).

    View shapes are handled by jax's trace cache inside the closure (a
    retrace bumps the trace counter), so a fixed-shape query stream
    compiles exactly once."""
    obs.count("engine.plan_miss")
    if route == "frontier":
        def run(view, qpts):
            _STATS["traces"] += 1
            obs.count("engine.trace")
            d2, ids = queries.knn_impl(view, qpts, k, param)
            return canonical_knn(d2, ids)
    elif route == "pallas-frontier":
        def run(view, qpts):
            _STATS["traces"] += 1
            obs.count("engine.trace")
            d2, ids = frontier_ops.knn_frontier_impl(
                view.pts, view.valid, view.active, view.bbox_lo,
                view.bbox_hi, qpts, k=k, impl=param)
            return canonical_knn(d2, ids)
    else:
        def run(view, qpts):
            _STATS["traces"] += 1
            obs.count("engine.trace")
            pts, ok = queries.flatten_view(view)
            d2, ids = knn_ops.knn_bruteforce_impl(qpts, pts, ok, k=k,
                                                  impl=param)
            return canonical_knn(d2, ids)
    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _range_count_closure(q: int, dim: int, dtype: str, max_rows: int):
    obs.count("engine.plan_miss")

    def run(view, lo, hi):
        _STATS["traces"] += 1
        obs.count("engine.trace")
        return queries.range_count_impl(view, lo, hi, max_rows)
    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _range_list_closure(q: int, dim: int, dtype: str, max_rows: int,
                        cap: int):
    obs.count("engine.plan_miss")

    def run(view, lo, hi):
        _STATS["traces"] += 1
        obs.count("engine.trace")
        return queries.range_list_impl(view, lo, hi, max_rows, cap)
    return jax.jit(run)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class QueryEngine:
    """Exact query planner/executor over leaf-row indexes.

    One engine instance rides along with each ``SpatialIndex`` /
    ``DistributedIndex`` handle (shared across functional updates) and
    holds only host-side planning state: the flat-scan budget and the
    converged buffer bucket per query kind. All device-side caching is
    in the module-level closure caches, shared process-wide.
    """

    def __init__(self, *, flat_budget: int = DEFAULT_FLAT_BUDGET,
                 start_rows: int = DEFAULT_MAX_ROWS,
                 start_cap: int = DEFAULT_CAP):
        self.flat_budget = flat_budget
        self.start_rows = start_rows
        self.start_cap = start_cap
        self._buckets: dict = {}

    # -- planner -----------------------------------------------------------

    def plan_knn(self, rows: int, cols: int, impl: str = "auto"):
        """Resolve an impl spelling to (route, static param): one of
        ("frontier", chunk), ("pallas-frontier", kernel_impl) or
        ("flat", kernel_impl)."""
        if impl == "interpret":
            raise ValueError(
                'impl="interpret" is not a spelling; use the canonical '
                '"pallas-interpret" (one name across engine and kernels)')
        if impl not in KNN_IMPLS:
            raise ValueError(f"unknown kNN impl {impl!r}; one of "
                             f"{KNN_IMPLS}")
        if impl == "auto":
            impl = "flat" if rows * cols <= self.flat_budget else \
                "pallas-frontier"
        if impl == "frontier":
            return "frontier", auto_chunk(rows)
        if impl in ("pallas-frontier", "pallas-frontier-interpret"):
            kernel = "auto" if impl == "pallas-frontier" else \
                "pallas-interpret"
            return "pallas-frontier", kernel
        kernel = {"flat": "auto", "pallas": "pallas",
                  "pallas-interpret": "pallas-interpret",
                  "ref": "ref"}[impl]
        return "flat", kernel

    # -- local queries -----------------------------------------------------

    def knn(self, view: queries.LeafView, qpts, k: int,
            impl: str = "auto"):
        """Exact batched kNN -> (d2 (Q, k) ascending, flat ids (Q, k) =
        row*C+slot, -1 padded), canonically (d2, id)-ordered."""
        rows, cols, dim = view.pts.shape
        route, param = self.plan_knn(rows, cols, impl)
        obs.count("engine.plan_request")
        obs.count(f"engine.route.{route}")
        fn = _knn_closure(qpts.shape[0], dim, str(qpts.dtype), int(k),
                          route, param)
        # opt-in compile-cost attribution (repro.obs.costs): charge this
        # plan's flops/bytes once per signature at the site that owns
        # the plan_miss counter; no-op on the default recorder. The view
        # shape is part of the signature — the compiled program (and so
        # its cost) depends on R x C, not just the closure-cache key.
        obs.costs.capture(
            fn, (view, qpts),
            f"knn.q{qpts.shape[0]}.k{int(k)}.{route}-{param}"
            f".v{rows}x{cols}")
        return fn(view, qpts)

    def range_count(self, view: queries.LeafView, lo, hi):
        """Exact batched range count -> counts (Q,). Escalates the row
        buffer through power-of-two buckets until nothing truncates."""
        rows = view.pts.shape[0]
        key = ("range_count", lo.shape[0], lo.shape[-1], str(lo.dtype))
        max_rows = min(_pow2(self._buckets.get(key, self.start_rows)),
                       _pow2(rows))
        obs.count("engine.plan_request")
        rounds = 0
        while True:
            fn = _range_count_closure(lo.shape[0], lo.shape[-1],
                                      str(lo.dtype), max_rows)
            obs.costs.capture(
                fn, (view, lo, hi),
                f"range_count.q{lo.shape[0]}.r{max_rows}"
                f".v{rows}x{view.pts.shape[1]}")
            cnt, trunc = fn(view, lo, hi)
            if max_rows >= rows or not bool(jnp.any(trunc)):
                self._buckets[key] = max_rows
                obs.observe("engine.escalation_rounds", rounds)
                return cnt
            rounds += 1
            obs.count("engine.escalation")
            max_rows = min(2 * max_rows, _pow2(rows))

    def range_list(self, view: queries.LeafView, lo, hi):
        """Exact batched range report -> (ids (Q, cap) flat row*C+slot
        padded with -1, counts (Q,)). ``cap`` is auto-sized: the output
        width is the converged power-of-two bucket (clamped to the
        gathered-slot count ``max_rows*C``), so every hit is always
        present."""
        rows, cols, _ = view.pts.shape
        key = ("range_list", lo.shape[0], lo.shape[-1], str(lo.dtype))
        max_rows, cap = self._buckets.get(key,
                                          (self.start_rows,
                                           self.start_cap))
        max_rows = min(_pow2(max_rows), _pow2(rows))
        # cap beyond the gathered slots is dead width (hits can't
        # exceed max_rows*C), so clamp — keeps the recorded bucket
        # equal to the actual output width when C isn't a power of two
        cap = min(_pow2(cap), max_rows * cols)
        obs.count("engine.plan_request")
        rounds = 0
        while True:
            fn = _range_list_closure(lo.shape[0], lo.shape[-1],
                                     str(lo.dtype), max_rows, cap)
            obs.costs.capture(
                fn, (view, lo, hi),
                f"range_list.q{lo.shape[0]}.r{max_rows}.c{cap}"
                f".v{rows}x{cols}")
            ids, cnt, rows_trunc = fn(view, lo, hi)
            need_rows = max_rows < rows and bool(jnp.any(rows_trunc))
            max_cnt = int(jnp.max(cnt)) if cnt.size else 0
            need_cap = cap < max_cnt
            if not (need_rows or need_cap):
                self._buckets[key] = (max_rows, cap)
                obs.observe("engine.escalation_rounds", rounds)
                return ids, cnt
            rounds += 1
            obs.count("engine.escalation")
            if need_rows:
                max_rows = min(2 * max_rows, _pow2(rows))
            if need_cap:
                # counts are exact once rows fit, so jump straight to
                # the bucket that holds them
                cap = max(2 * cap, _pow2(max_cnt))
            cap = min(cap, max_rows * cols)

    # -- distributed queries (shard-merge step) ----------------------------

    def knn_dist(self, index, qpts, k: int, mesh, impl: str = "auto"):
        """Exact distributed kNN -> (d2, neighbor points, valid): each
        shard answers locally (frontier or flat scan — unjitted inside
        shard_map), then the merge takes the top-k of per-shard top-k."""
        from . import distributed as D
        rows, cols = index.tree.pts.shape[-3], index.tree.pts.shape[-2]
        route, param = self.plan_knn(rows, cols, impl)
        obs.count("engine.plan_request")
        obs.count(f"engine.route.{route}")
        if route == "frontier":
            return D.knn(index, qpts, k, mesh, chunk=param)
        if route == "pallas-frontier":
            return D.knn(index, qpts, k, mesh, impl="pallas-frontier",
                         kernel=param)
        return D.knn(index, qpts, k, mesh, impl="flat", kernel=param)

    def range_count_dist(self, index, lo, hi, mesh):
        """Exact distributed range count -> counts (Q,): per-shard
        count + psum, re-run at escalated row buckets until no shard
        truncates."""
        from . import distributed as D
        rows = index.tree.pts.shape[-3]
        key = ("range_count_dist", lo.shape[0], lo.shape[-1],
               str(lo.dtype))
        max_rows = min(_pow2(self._buckets.get(key, self.start_rows)),
                       _pow2(rows))
        obs.count("engine.plan_request")
        rounds = 0
        while True:
            cnt, trunc = D.range_count(index, lo, hi, mesh,
                                       max_rows=max_rows)
            if max_rows >= rows or not bool(jnp.any(trunc)):
                self._buckets[key] = max_rows
                obs.observe("engine.escalation_rounds", rounds)
                return cnt
            rounds += 1
            obs.count("engine.escalation")
            max_rows = min(2 * max_rows, _pow2(rows))
