"""Facade parity + auto-capacity tests for the unified Index API.

Every registered backend must produce bit-identical trees and query
answers through ``make_index`` as through the raw module calls with the
same parameters, and the facade must absorb capacity overflows without
the caller ever seeing ``overflowed``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BACKENDS, baselines, capacity_for, engine,
                        get_backend, make_index, porth, queries, spac)

PHI = 8
N, M = 1200, 400
ROOT_LO = jnp.zeros(2, jnp.int32)
ROOT_HI = jnp.full(2, 1 << 20, jnp.int32)


def gen_points(seed, n, lo=0, hi=1 << 20):
    rng = np.random.default_rng(seed)
    return rng.integers(lo, hi, size=(n, 2)).astype(np.int32)


PTS = jnp.asarray(gen_points(0, N))
BATCH = jnp.asarray(gen_points(1, M))
QS = jnp.asarray(gen_points(2, 32))


def direct_build(kind, pts, cap):
    if kind == "porth":
        return porth.build(pts, ROOT_LO, ROOT_HI, phi=PHI, lam=3, rounds=5,
                           capacity_rows=cap)
    if kind in ("spac-h", "spac-z", "spac-m", "cpam-h", "cpam-z"):
        return spac.build(pts, phi=PHI, curve=get_backend(kind).curve,
                          bits=16, coord_bits=30, capacity_rows=cap)
    if kind == "kd":
        return baselines.kd_build(pts, phi=PHI, max_depth=24,
                                  capacity_rows=cap)
    if kind == "zd":
        return baselines.zd_build(pts, phi=PHI, bits=15, coord_bits=20,
                                  lam=3, capacity_rows=cap)
    raise AssertionError(kind)


def direct_insert(kind, tree, batch, cap):
    if kind == "porth":
        return porth.insert(tree, batch,
                            max_overflow_rows=min(64, tree.pts.shape[0]))
    if kind in ("spac-h", "spac-z", "spac-m", "cpam-h", "cpam-z"):
        return spac.insert(tree, batch,
                           max_overflow_rows=min(64, tree.pts.shape[0]),
                           sort_rows=kind.startswith("cpam"))
    if kind == "kd":
        return baselines.kd_insert(tree, batch, max_depth=24,
                                   capacity_rows=cap)
    return baselines.zd_insert(tree, batch, bits=15, coord_bits=20, lam=3,
                               capacity_rows=cap)


def direct_delete(kind, tree, batch, cap):
    if kind == "porth":
        return porth.delete(tree, batch)
    if kind in ("spac-h", "spac-z", "spac-m", "cpam-h", "cpam-z"):
        return spac.delete(tree, batch)
    if kind == "kd":
        return baselines.kd_delete(tree, batch, max_depth=24,
                                   capacity_rows=cap)
    return baselines.zd_delete(tree, batch, bits=15, coord_bits=20, lam=3,
                               capacity_rows=cap)


def assert_trees_bitmatch(a, b, kind, stage):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), (kind, stage)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{kind}: {stage} diverged from the direct module call")


@pytest.mark.parametrize("kind", sorted(BACKENDS))
def test_facade_parity(kind):
    """make_index build/insert/delete/knn/range bit-match direct calls."""
    cap = capacity_for(N + M, PHI, get_backend(kind).cap_slack)
    idx = make_index(kind, PTS, phi=PHI, capacity_rows=cap,
                     **(dict(root_lo=ROOT_LO, root_hi=ROOT_HI)
                        if kind == "porth" else {}))
    ref = direct_build(kind, PTS, cap)
    assert_trees_bitmatch(idx.tree, ref, kind, "build")

    idx2 = idx.insert(BATCH)
    ref2 = direct_insert(kind, ref, BATCH, idx2.capacity_rows)
    assert_trees_bitmatch(idx2.tree, ref2, kind, "insert")

    idx3 = idx2.delete(PTS[:200])
    ref3 = direct_delete(kind, ref2, PTS[:200], idx3.capacity_rows)
    assert_trees_bitmatch(idx3.tree, ref3, kind, "delete")

    # facade kNN = canonically-ordered direct engine call (the facade
    # sorts each query's hits by (d2, id) so impls are comparable)
    d2_f, ids_f = idx3.knn(QS, 5, impl="frontier")
    d2_r, ids_r = engine.canonical_knn(*queries.knn(ref3.view(), QS, 5))
    np.testing.assert_array_equal(np.asarray(d2_f), np.asarray(d2_r))
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_r))

    lo = QS
    hi = QS + jnp.int32(1 << 17)
    cnt_f = idx3.range_count(lo, hi)
    cnt_r, tr_r = queries.range_count(ref3.view(), lo, hi, max_rows=1024)
    assert not bool(jnp.any(tr_r))
    np.testing.assert_array_equal(np.asarray(cnt_f), np.asarray(cnt_r))
    ids_lf, c_lf = idx3.range_list(lo, hi)
    ids_lr, c_lr, tr_l = queries.range_list(ref3.view(), lo, hi,
                                            max_rows=1024, cap=256)
    assert not bool(jnp.any(tr_l))
    np.testing.assert_array_equal(np.asarray(c_lf), np.asarray(c_lr))
    # same hits in the same (ascending flat-id) order; facade width is
    # the engine's auto-sized bucket, padded with -1 past the count
    for qi in range(QS.shape[0]):
        c = int(c_lr[qi])
        np.testing.assert_array_equal(np.asarray(ids_lf[qi, :c]),
                                      np.asarray(ids_lr[qi, :c]))
        assert (np.asarray(ids_lf[qi, c:]) == -1).all()


@pytest.mark.parametrize("kind", ["porth", "spac-h", "spac-z"])
def test_facade_autogrow(kind):
    """Inserting far past capacity recovers transparently — the caller
    never sees ``overflowed`` and every point survives."""
    idx = make_index(kind, PTS[:64], phi=PHI, capacity_rows=32)
    assert not bool(idx.tree.overflowed)
    idx = idx.insert(PTS[64:])          # ~18x the original capacity
    assert not bool(idx.tree.overflowed)
    assert len(idx) == N
    assert idx.capacity_rows > 32
    # exactness survives the grow/compact ladder
    d2, _ = idx.knn(QS[:8], 5)
    live, ok = idx.extract_points()
    live = np.asarray(live)[np.asarray(ok)]
    for i in range(8):
        bf = np.sort(((live.astype(np.float64)
                       - np.asarray(QS[i], np.float64)) ** 2).sum(-1))[:5]
        np.testing.assert_allclose(np.asarray(d2[i], np.float64), bf,
                                   rtol=1e-6)


def test_facade_autogrow_rebuild_backends():
    """Rebuild-style backends (kd/zd) also absorb growth: capacity is
    re-derived per update so nothing is silently dropped."""
    for kind in ("kd", "zd"):
        idx = make_index(kind, PTS[:64], phi=PHI)
        idx = idx.insert(PTS[64:])
        assert len(idx) == N, kind


def test_rebuild_insert_clustered_no_silent_drop():
    """Clustered data needs far more rows than the slack heuristic; the
    rebuild insert path must size-check and retry, not drop silently
    (regression: zd lost 2902/4950 points before the check)."""
    rng = np.random.default_rng(0)
    centers = rng.integers(0, 1 << 20, size=(150, 2)).astype(np.int32)
    offs = (np.arange(33) * (1 << 5)).astype(np.int32)
    pts = (centers[:, None, :]
           + np.stack([offs, offs], -1)[None]).reshape(-1, 2)
    pts = np.clip(pts, 0, (1 << 20) - 1).astype(np.int32)
    for kind in ("zd", "kd"):
        idx = make_index(kind, pts[:64], phi=PHI)
        idx = idx.insert(pts[64:])
        assert len(idx) == len(pts), (kind, len(idx))


def test_build_overflow_retries():
    """A build at absurdly small explicit capacity succeeds anyway."""
    idx = make_index("spac-h", PTS, phi=PHI, capacity_rows=2)
    assert len(idx) == N
    idx = make_index("porth", PTS, phi=PHI, capacity_rows=2)
    assert len(idx) == N


def test_masked_updates():
    mask = jnp.arange(M) < (M // 2)
    idx = make_index("spac-h", PTS, phi=PHI)
    idx = idx.insert(BATCH, mask)
    assert len(idx) == N + M // 2
    idx = idx.delete(BATCH, mask)
    assert len(idx) == N


def test_registry_errors():
    with pytest.raises(KeyError, match="unknown index kind"):
        make_index("rtree", PTS)
    with pytest.raises(TypeError, match="unknown params"):
        make_index("spac-h", PTS, curve="hilbert", lam=3)  # lam is porth's
    with pytest.raises(ValueError, match="spac-family"):
        from repro.core.index import DistributedIndex
        DistributedIndex.build("kd", PTS, mesh=None)


def test_update_closures_cached():
    """Same (backend, shape, dtype, params) reuses one jitted closure."""
    from repro.core.index import _update_closure
    _update_closure.cache_clear()
    idx = make_index("spac-h", PTS, phi=PHI)
    idx = idx.insert(BATCH).insert(gen_points(7, M)).delete(BATCH)
    info = _update_closure.cache_info()
    assert info.misses == 2          # one insert + one delete closure
    assert info.hits >= 1            # second same-shape insert reused it

    # knn on the facade is the module-level jitted engine: cached too
    d2a, _ = idx.knn(QS, 5)
    d2b, _ = idx.knn(QS, 5)
    np.testing.assert_array_equal(np.asarray(d2a), np.asarray(d2b))


def test_size_and_views():
    idx = make_index("porth", PTS, phi=PHI)
    assert int(idx.size) == len(idx) == N
    view = idx.view()
    assert view.pts.shape[0] == idx.capacity_rows
    pts, ok = idx.extract_points()
    assert int(ok.sum()) == N


def _run_distributed(script: str):
    """Run a distributed scenario on the simulated 8-device mesh (one
    scenario per process keeps each under the compile-time budget of a
    small CPU box)."""
    from helpers import run_on_simulated_mesh
    run_on_simulated_mesh(_DIST_PRELUDE + script, 8,
                          timeout_base_s=1200, expect="RECOVERY_OK")


_DIST_PRELUDE = r"""
import jax
from repro.core import make_index
from repro.data import points as gen
"""


@pytest.mark.slow
def test_distributed_row_overflow_recovery():
    """Shard-row overflow re-shards at doubled capacity: no point lost,
    callers never see ``overflowed``."""
    _run_distributed(r"""
pts = gen.uniform(jax.random.PRNGKey(0), 2048, 2)
idx = make_index("spac-h", pts, mesh=mesh, phi=8, capacity_rows=40)
idx = idx.insert(gen.uniform(jax.random.PRNGKey(1), 4096, 2))
assert len(idx) == 6144, len(idx)
assert int(idx.dropped) == 0
print("RECOVERY_OK")
""")


@pytest.mark.slow
def test_distributed_slab_overflow_recovery():
    """A skewed delete under a deliberately tight routing slab escalates
    slack instead of silently skipping the overflowed deletions."""
    _run_distributed(r"""
sw = gen.sweepline(jax.random.PRNGKey(4), 2048, 2)
sidx = make_index("spac-h", sw, mesh=mesh, phi=8)
sidx.slack = 0.25
sidx = sidx.delete(sw[:512])
assert len(sidx) == 1536, len(sidx)
assert int(sidx.dropped) == 0
print("RECOVERY_OK")
""")
