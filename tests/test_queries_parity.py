"""Query-engine parity + exactness tests (the Query API contract).

Every registered backend must answer kNN and range queries *exactly*
through the facade — no ``max_rows``/``cap`` knobs, no ``truncated``
flag — and the engine's execution routes (chunked frontier traversal vs
Pallas brute-force flat scan) must agree bit-for-bit with each other
and with a numpy oracle.

The parity data uses integer coordinates < 2^10 so every intermediate
of both distance formulas (the frontier's (q-p)^2 sum and the kernel's
|q|^2 - 2qp + |p|^2 MXU form) is an integer below 2^24 — exactly
representable in float32 — and the seed is chosen so no query has a
tie at the k boundary. Under those conditions "identical ids/d2" is
well-defined and asserted with assert_array_equal.

The fused frontier kernel (impl="pallas-frontier") carries a stronger
guarantee: its *centered* MXU identity subtracts the per-group bbox
midpoint before the matmul, so exactness needs only the tile-local
spread in the window, not the absolute coordinates — asserted by the
adversarial large-magnitude test below, where the plain identity is
off by orders of magnitude.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_on_simulated_mesh
from repro.core import BACKENDS, engine, make_index, queries

PHI = 8
N, Q, K = 700, 16, 5
COORD_HI = 1 << 10          # exact-arithmetic window (see module doc)
IMPLS = ("frontier", "flat", "pallas-interpret", "pallas-frontier",
         "pallas-frontier-interpret", "ref")


def oracle_knn_d2(pts: np.ndarray, qs: np.ndarray, k: int) -> np.ndarray:
    d2 = ((pts[None].astype(np.int64)
           - qs[:, None].astype(np.int64)) ** 2).sum(-1)
    return np.sort(d2, axis=1)[:, :k]


def oracle_range_count(pts: np.ndarray, lo: np.ndarray,
                       hi: np.ndarray) -> np.ndarray:
    inside = ((pts[None] >= lo[:, None]) & (pts[None] <= hi[:, None]))
    return inside.all(-1).sum(-1).astype(np.int64)


def _tie_free_data(n: int, q: int, k: int):
    """Points/queries with no distance tie at any query's k boundary
    (makes top-k id sets unique, so impl outputs must be identical)."""
    for seed in range(64):
        rng = np.random.default_rng(seed)
        pts = rng.integers(0, COORD_HI, size=(n, 2)).astype(np.int32)
        qs = rng.integers(0, COORD_HI, size=(q, 2)).astype(np.int32)
        d2 = np.sort(((pts[None].astype(np.int64)
                       - qs[:, None].astype(np.int64)) ** 2).sum(-1), 1)
        if (d2[:, k - 1] != d2[:, k]).all():
            return pts, qs
    raise AssertionError("no tie-free seed found")


PTS, QS = _tie_free_data(N, Q, K)


@pytest.fixture(scope="module")
def indexes():
    """One facade index per registered backend over the shared data."""
    return {kind: make_index(kind, jnp.asarray(PTS), phi=PHI)
            for kind in sorted(BACKENDS)}


# ---------------------------------------------------------------------------
# kNN parity: engine impls x backends vs the numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(BACKENDS))
def test_knn_impl_parity(indexes, kind):
    """Every impl route — chunked frontier, flat scan (jnp and Pallas
    interpret), fused frontier (ref and Pallas interpret) — returns
    identical ids/d2 and matches the numpy oracle bit-for-bit."""
    idx = indexes[kind]
    want_d2 = oracle_knn_d2(PTS, np.asarray(QS), K)
    results = {impl: idx.knn(QS, K, impl=impl) for impl in IMPLS}
    for impl, (d2, ids) in results.items():
        np.testing.assert_array_equal(
            np.asarray(d2, np.int64), want_d2,
            err_msg=f"{kind}/{impl}: d2 diverged from the oracle")
        # ids resolve to points at exactly the claimed distances
        nbrs = np.asarray(queries.gather_points(idx.view(), ids),
                          np.int64)
        got = ((nbrs - np.asarray(QS, np.int64)[:, None]) ** 2).sum(-1)
        np.testing.assert_array_equal(got, want_d2, err_msg=f"{kind}/"
                                      f"{impl}: ids decode wrong")
    base_d2, base_ids = results["frontier"]
    for impl in IMPLS[1:]:
        d2, ids = results[impl]
        np.testing.assert_array_equal(np.asarray(d2), np.asarray(base_d2))
        np.testing.assert_array_equal(
            np.asarray(ids), np.asarray(base_ids),
            err_msg=f"{kind}: {impl} ids != frontier ids")


def test_knn_auto_routes_and_matches(indexes):
    """impl="auto" (flat scan at this size) equals the forced paths."""
    idx = indexes["spac-h"]
    rows, cols, _ = idx.view().pts.shape
    assert rows * cols <= idx.engine.flat_budget  # flat route chosen
    d2_a, ids_a = idx.knn(QS, K)
    d2_f, ids_f = idx.knn(QS, K, impl="frontier")
    np.testing.assert_array_equal(np.asarray(d2_a), np.asarray(d2_f))
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_f))


def test_knn_fewer_points_than_k(indexes):
    """Both routes pad identically when the index holds < k points."""
    idx = make_index("spac-h", jnp.asarray(PTS[:3]), phi=PHI)
    for impl in IMPLS:
        d2, ids = idx.knn(QS, 8, impl=impl)
        assert (np.asarray(ids)[:, 3:] == -1).all(), impl
        assert (np.asarray(ids)[:, :3] >= 0).all(), impl


def test_knn_engine_rejects_legacy_interpret_alias():
    """One canonical interpret spelling across layers: the engine and
    the kernel boundary both reject the legacy alias with the same
    pointer to the canonical name."""
    idx = make_index("spac-h", jnp.asarray(PTS), phi=PHI)
    with pytest.raises(ValueError, match="pallas-interpret"):
        idx.knn(QS, K, impl="interpret")
    with pytest.raises(ValueError, match="unknown kNN impl"):
        idx.knn(QS, K, impl="bruteforce")


# ---------------------------------------------------------------------------
# compensated distances: exact outside the absolute f32 window
# ---------------------------------------------------------------------------

_ADV_OFFSET = 1 << 23       # every coordinate far outside |q|^2 exactness
_ADV_SPREAD = 1 << 9        # tile-local spread well inside the window


def _adversarial_data(n: int, q: int, k: int):
    """Tie-free points/queries at offset 2^23 with spread < 2^9: every
    coordinate is an exactly-representable f32 integer, (q-p) stays
    exact (< 2^10), but |q|^2 ~ 7e13 has ulp 2^23 — the plain MXU
    identity cannot even represent its own intermediates."""
    for seed in range(64):
        rng = np.random.default_rng(seed + 100)
        pts = (_ADV_OFFSET + rng.integers(0, _ADV_SPREAD, size=(n, 2))
               ).astype(np.int32)
        qs = (_ADV_OFFSET + rng.integers(0, _ADV_SPREAD, size=(q, 2))
              ).astype(np.int32)
        d2 = np.sort(((pts[None].astype(np.int64)
                       - qs[:, None].astype(np.int64)) ** 2).sum(-1), 1)
        if (d2[:, k - 1] != d2[:, k]).all():
            return pts, qs
    raise AssertionError("no tie-free adversarial seed found")


def test_plain_mxu_identity_rounds_at_large_magnitude():
    """Precondition for the parity test below: on the adversarial data
    the *uncentered* |q|^2 - 2qp + |p|^2 form diverges from the exact
    (q-p)^2 distances — catastrophically, not in the last ulp."""
    pts, qs = _adversarial_data(300, 8, K)
    exact = ((pts[None].astype(np.int64)
              - qs[:, None].astype(np.int64)) ** 2).sum(-1)
    qf = jnp.asarray(qs, jnp.float32)
    pf = jnp.asarray(pts, jnp.float32)
    plain = ((qf * qf).sum(-1)[:, None]
             - 2.0 * qf @ pf.T + (pf * pf).sum(-1)[None, :])
    err = np.abs(np.asarray(plain, np.float64) - exact)
    assert err.max() > _ADV_SPREAD ** 2, err.max()


@pytest.mark.parametrize("kind", sorted(BACKENDS))
def test_knn_compensated_parity_outside_f32_window(kind):
    """impl="pallas-frontier" (and its interpret spelling) is bit-exact
    against impl="frontier" and the int64 oracle on coordinates far
    outside the absolute f32-exact window: the centered identity only
    needs the tile-local spread in the window."""
    pts, qs = _adversarial_data(300, 8, K)
    idx = make_index(kind, jnp.asarray(pts), phi=PHI)
    want_d2 = oracle_knn_d2(pts, qs, K)
    base_d2, base_ids = idx.knn(jnp.asarray(qs), K, impl="frontier")
    np.testing.assert_array_equal(np.asarray(base_d2, np.int64), want_d2,
                                  err_msg=f"{kind}: frontier not exact")
    for impl in ("pallas-frontier", "pallas-frontier-interpret"):
        d2, ids = idx.knn(jnp.asarray(qs), K, impl=impl)
        np.testing.assert_array_equal(
            np.asarray(d2), np.asarray(base_d2),
            err_msg=f"{kind}/{impl}: d2 != frontier d2")
        np.testing.assert_array_equal(
            np.asarray(ids), np.asarray(base_ids),
            err_msg=f"{kind}/{impl}: ids != frontier ids")


# ---------------------------------------------------------------------------
# _range_rows: top_k candidate selection == old argsort (regression)
# ---------------------------------------------------------------------------

def test_range_rows_topk_matches_argsort_reference():
    """`_range_rows` now selects candidate rows with `lax.top_k` on a
    negated key; it must reproduce the old full-argsort spelling bit
    for bit (same rows, same order, same flags) at every bucket size,
    including buckets past R."""
    rng = np.random.default_rng(5)
    pts = rng.integers(0, 1 << 20, size=(3000, 2)).astype(np.int32)
    idx = make_index("spac-h", jnp.asarray(pts), phi=PHI)
    view = idx.view()
    R = view.pts.shape[0]
    for t in range(10):
        lo = jnp.asarray(rng.integers(0, 1 << 19, 2), jnp.int32)
        hi = lo + jnp.asarray(rng.integers(1, 1 << 19, 2), jnp.int32)
        overlap = np.asarray(
            queries._boxes_overlap(view.bbox_lo, view.bbox_hi,
                                   lo[None, :], hi[None, :])
            & view.active)
        for max_rows in (4, 128, R, 2 * R):
            rows, rows_ok, trunc = queries._range_rows(
                view, lo, hi, max_rows)
            key = np.where(overlap, np.arange(R), R)
            want = np.argsort(key, kind="stable")[:max_rows]
            np.testing.assert_array_equal(np.asarray(rows), want)
            np.testing.assert_array_equal(np.asarray(rows_ok),
                                          overlap[want])
            assert bool(trunc) == (int(overlap.sum()) > max_rows)


# ---------------------------------------------------------------------------
# range exactness: auto-sized buffers, no knobs, no truncated flag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(BACKENDS))
def test_range_count_oracle(indexes, kind):
    rng = np.random.default_rng(7)
    lo = rng.integers(0, COORD_HI // 2, size=(Q, 2)).astype(np.int32)
    hi = lo + rng.integers(1, COORD_HI // 2, size=(Q, 2)).astype(np.int32)
    cnt = indexes[kind].range_count(jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_array_equal(np.asarray(cnt, np.int64),
                                  oracle_range_count(PTS, lo, hi))


def test_range_exceeding_old_default_is_exact():
    """Regression for the silent-inexactness bug: a query overlapping
    far more rows than the old ``max_rows=128`` default returns the
    exact count/list through the facade (pre-engine, fig5_range.py and
    launch/serve.py dropped ``truncated`` and served short answers)."""
    rng = np.random.default_rng(1)
    n = 4000
    pts = rng.integers(0, 1 << 20, size=(n, 2)).astype(np.int32)
    idx = make_index("spac-h", jnp.asarray(pts), phi=PHI)
    lo = jnp.zeros((2, 2), jnp.int32)
    hi = jnp.full((2, 2), (1 << 20) - 1, jnp.int32)
    # precondition: the old fixed-capacity engine *does* truncate here
    _, trunc = queries.range_count(idx.view(), lo, hi, max_rows=128)
    assert bool(jnp.all(trunc)), "scenario no longer exceeds 128 rows"
    cnt = idx.range_count(lo, hi)
    assert (np.asarray(cnt) == n).all(), np.asarray(cnt)
    ids, cnt_l = idx.range_list(lo, hi)
    assert (np.asarray(cnt_l) == n).all()
    assert int((np.asarray(ids) >= 0).sum()) == 2 * n


@pytest.mark.parametrize("kind", sorted(BACKENDS))
def test_range_list_matches_count(indexes, kind):
    rng = np.random.default_rng(11)
    lo = rng.integers(0, COORD_HI // 2, size=(8, 2)).astype(np.int32)
    hi = lo + np.int32(COORD_HI // 3)
    idx = indexes[kind]
    ids, cnt = idx.range_list(jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_array_equal(np.asarray(cnt, np.int64),
                                  oracle_range_count(PTS, lo, hi))
    ids_np = np.asarray(ids)
    np.testing.assert_array_equal((ids_np >= 0).sum(-1), np.asarray(cnt))
    # every reported id decodes to a point inside its box
    nbrs = np.asarray(queries.gather_points(idx.view(), ids))
    for qi in range(lo.shape[0]):
        sel = ids_np[qi] >= 0
        inside = ((nbrs[qi, sel] >= lo[qi]) &
                  (nbrs[qi, sel] <= hi[qi])).all(-1)
        assert inside.all(), (kind, qi)


def test_range_list_non_pow2_slot_width():
    """With a non-power-of-two row width (phi=5 -> C=10) the escalated
    cap clamps to the gathered-slot count, so the returned ids width
    always equals the engine's recorded bucket and no hit is lost."""
    rng = np.random.default_rng(3)
    n = 1500
    pts = rng.integers(0, 1 << 20, size=(n, 2)).astype(np.int32)
    idx = make_index("spac-h", jnp.asarray(pts), phi=5)
    lo = jnp.zeros((2, 2), jnp.int32)
    hi = jnp.full((2, 2), (1 << 20) - 1, jnp.int32)
    ids, cnt = idx.range_list(lo, hi)
    assert (np.asarray(cnt) == n).all()
    assert int((np.asarray(ids) >= 0).sum()) == 2 * n
    _, cap = idx.engine._buckets[("range_list", 2, 2, "int32")]
    assert ids.shape[1] == cap


# ---------------------------------------------------------------------------
# retrace bound: escalation is O(log R) and remembered
# ---------------------------------------------------------------------------

def test_range_escalation_trace_bound():
    """From a deliberately tiny starting bucket, the engine reaches the
    exact answer in <= log2(R) + 1 traces, and an identical follow-up
    query re-traces zero times (bucket remembered + jit cache)."""
    rng = np.random.default_rng(2)
    n = 2000
    pts = rng.integers(0, 1 << 20, size=(n, 2)).astype(np.int32)
    idx = make_index("spac-h", jnp.asarray(pts), phi=PHI)
    idx.engine.start_rows = 8
    rows = idx.capacity_rows
    lo = jnp.zeros((4, 2), jnp.int32)
    hi = jnp.full((4, 2), (1 << 20) - 1, jnp.int32)

    engine._range_count_closure.cache_clear()
    engine.reset_trace_count()
    cnt = idx.range_count(lo, hi)
    assert (np.asarray(cnt) == n).all()
    traces = engine.trace_count()
    bound = int(np.ceil(np.log2(rows))) + 1
    assert 2 <= traces <= bound, (traces, bound)

    # steady state: converged bucket is remembered, nothing re-traces
    cnt2 = idx.range_count(lo, hi)
    assert engine.trace_count() == traces
    np.testing.assert_array_equal(np.asarray(cnt2), np.asarray(cnt))

    # the update stream keeps the engine: queries after an insert reuse
    # the converged bucket (same closure, jax retraces only for the new
    # tree shape if capacity grew)
    idx2 = idx.insert(jnp.asarray(
        rng.integers(0, 1 << 20, size=(64, 2)).astype(np.int32)))
    cnt3 = idx2.range_count(lo, hi)
    assert (np.asarray(cnt3) == n + 64).all()


def test_knn_closures_cached_per_shape():
    """Fixed-shape kNN streams compile once per (Q, k, impl) plan."""
    idx = make_index("spac-h", jnp.asarray(PTS), phi=PHI)
    engine._knn_closure.cache_clear()
    engine.reset_trace_count()
    for _ in range(3):
        idx.knn(QS, K, impl="frontier")
    assert engine.trace_count() == 1
    idx.knn(QS, K, impl="ref")       # different plan, one more trace
    assert engine.trace_count() == 2


# ---------------------------------------------------------------------------
# property tests (hypothesis, where available)
# ---------------------------------------------------------------------------

def test_prop_range_count_exact():
    """Hypothesis sweep (skipped where hypothesis is unavailable):
    facade range counts equal the numpy oracle for arbitrary data."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(20, 200))
    def check(seed, n):
        rng = np.random.default_rng(seed)
        pts = rng.integers(0, 512, size=(n, 2)).astype(np.int32)
        idx = make_index("spac-h", jnp.asarray(pts), phi=PHI)
        lo = rng.integers(0, 400, size=(4, 2)).astype(np.int32)
        hi = lo + rng.integers(0, 300, size=(4, 2)).astype(np.int32)
        cnt = idx.range_count(jnp.asarray(lo), jnp.asarray(hi))
        np.testing.assert_array_equal(np.asarray(cnt, np.int64),
                                      oracle_range_count(pts, lo, hi))

    check()


def test_prop_knn_d2_exact():
    """Hypothesis sweep: engine kNN distances equal the oracle for all
    impls on arbitrary (exact-arithmetic-window) data."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(10, 150),
           st.sampled_from(["frontier", "pallas-interpret",
                            "pallas-frontier", "ref"]))
    def check(seed, n, impl):
        rng = np.random.default_rng(seed)
        pts = rng.integers(0, 512, size=(n, 2)).astype(np.int32)
        qs = rng.integers(0, 512, size=(4, 2)).astype(np.int32)
        k = min(4, n)
        idx = make_index("spac-z", jnp.asarray(pts), phi=PHI)
        d2, _ = idx.knn(jnp.asarray(qs), k, impl=impl)
        np.testing.assert_array_equal(np.asarray(d2, np.int64),
                                      oracle_knn_d2(pts, qs, k))

    check()


# ---------------------------------------------------------------------------
# distributed: same engine, shard-merge step (8 forced host devices)
# ---------------------------------------------------------------------------

_DIST_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import make_index
from repro.data import points as gen

pts = gen.uniform(jax.random.PRNGKey(0), 4096, 2)
idx = make_index("spac-h", pts, mesh=mesh, phi=8)
qs = gen.uniform(jax.random.PRNGKey(2), 16, 2)

# kNN through the engine: auto (flat scan at this shard size), the
# forced frontier route and the fused frontier kernel agree with host
# brute force
allp = np.asarray(pts, np.float64)
for impl in ("auto", "frontier", "pallas-frontier"):
    d2, bp, ok = idx.knn(qs, 5, impl=impl)
    for i in range(16):
        bf = np.sort(((allp - np.asarray(qs[i], np.float64)) ** 2
                      ).sum(-1))[:5]
        got = np.sort(np.asarray(d2[i], np.float64))
        assert np.allclose(got, bf), (impl, i, got, bf)

# range count through the engine from a tiny starting bucket: the
# escalation loop wraps the whole shard_map exchange and converges to
# the exact global count
idx.engine.start_rows = 8
lo = jnp.zeros((2, 2), jnp.int32)
hi = jnp.full((2, 2), (1 << 20) - 1, jnp.int32)
cnt = idx.range_count(lo, hi)
assert (np.asarray(cnt) == 4096).all(), np.asarray(cnt)
print("DIST_ENGINE_OK")
"""


def test_distributed_engine_queries():
    # fast-tier mesh smoke: the 8-device simulated mesh exercises the
    # full distributed query path (see tests/helpers.py)
    run_on_simulated_mesh(_DIST_SCRIPT, 8, timeout_base_s=900,
                          expect="DIST_ENGINE_OK")
