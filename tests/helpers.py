"""Shared helpers for the subprocess-based distributed tests.

Those tests force 8 host devices (``--xla_force_host_platform_device_count``)
in a child process; their wall time is dominated by 8-way shard_map
compiles that parallelize across cores. The historical timeout budgets
were tuned on ~4-core CI boxes and flake on 1-core ones, where the same
work takes roughly 4x as long — so the budget scales with
``os.cpu_count()`` instead of being a constant.
"""

from __future__ import annotations

import os


def scaled_timeout(base_s: float, devices: int = 8) -> float:
    """Subprocess timeout: ``base_s`` (the >= devices/2-core budget)
    stretched by the core deficit, so a 1-core box gets 4x the 4-core
    budget rather than a flaky kill."""
    cores = os.cpu_count() or 1
    return base_s * max(1.0, devices / (2.0 * cores))
