"""Shared helpers for the subprocess-based distributed tests.

Those tests force 8 host devices (``--xla_force_host_platform_device_count``)
in a child process; their wall time is dominated by 8-way shard_map
compiles that parallelize across cores. The historical timeout budgets
were tuned on ~4-core CI boxes and flake on 1-core ones, where the same
work takes roughly 4x as long — so the budget scales with
``os.cpu_count()`` instead of being a constant.
"""

from __future__ import annotations

import os
import subprocess
import sys


def scaled_timeout(base_s: float, devices: int = 8) -> float:
    """Subprocess timeout: ``base_s`` (the >= devices/2-core budget)
    stretched by the core deficit, so a 1-core box gets 4x the 4-core
    budget rather than a flaky kill."""
    cores = os.cpu_count() or 1
    return base_s * max(1.0, devices / (2.0 * cores))


def run_on_simulated_mesh(script: str, n_devices: int = 8, *,
                          timeout_base_s: float = 900.0,
                          expect: str | None = None):
    """Run ``script`` in a child process on a simulated ``n_devices``
    CPU mesh (``repro.configs.platform.simulate_mesh``).

    The forced host device count must be staged before jax initializes,
    which a pytest process (whose earlier tests already touched jax)
    cannot do — so the script runs in a fresh interpreter with a
    prelude that stages the platform *first* and binds the resulting
    1-D device mesh to the name ``mesh``. When ``expect`` is given the
    child's stdout must contain it (stderr is attached to the assertion
    for debugging); the completed process is returned either way."""
    prelude = ("from repro.configs import platform as _platform\n"
               f"mesh = _platform.simulate_mesh({int(n_devices)})\n")
    out = subprocess.run(
        [sys.executable, "-c", prelude + script], capture_output=True,
        text=True, timeout=scaled_timeout(timeout_base_s, n_devices),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    if expect is not None:
        assert expect in out.stdout, out.stdout + out.stderr
    return out
