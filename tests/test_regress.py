"""repro.obs.regress: direction-aware tolerance bands, missing-metric
handling, the inject self-test hook, baseline structural validation,
and the CLI round-trip (--update -> gate -> --replay) on fake suites.

The real suites re-run the smoke tier (minutes); these tests swap in a
deterministic fake so the gate's *mechanics* are pinned fast — the
real run is exercised by CI's perf-gate step.
"""

from __future__ import annotations

import json

import pytest

from repro.obs import regress


def _metrics():
    return {
        "t.p50_ms": regress.metric(10.0),
        "t.qps": regress.metric(500.0, "higher"),
        "s.bytes": regress.metric(100_000, "lower", "struct"),
        "s.avg_out": regress.metric(0.2, "higher", "struct"),
    }


# -- compare ---------------------------------------------------------------

def test_identical_runs_pass():
    rows, n = regress.compare(_metrics(), _metrics(), 1.0, 0.25)
    assert n == 0
    assert {r[4] for r in rows} == {"ok"}


def test_direction_aware_bands():
    cur = _metrics()
    cur["t.p50_ms"]["value"] = 25.0       # 2.5x slower: out of 2x band
    cur["t.qps"]["value"] = 180.0         # 2.8x less throughput
    cur["s.bytes"]["value"] = 130_000     # +30% memory: out of 25%
    rows, n = regress.compare(cur, _metrics(), 1.0, 0.25)
    assert n == 3
    status = {r[0]: r[4] for r in rows}
    assert status["t.p50_ms"] == "REGRESSED"
    assert status["t.qps"] == "REGRESSED"
    assert status["s.bytes"] == "REGRESSED"
    assert status["s.avg_out"] == "ok"


def test_improvements_do_not_fail():
    cur = _metrics()
    cur["t.p50_ms"]["value"] = 2.0        # 5x faster
    cur["t.qps"]["value"] = 5_000.0
    rows, n = regress.compare(cur, _metrics(), 1.0, 0.25)
    assert n == 0
    status = {r[0]: r[4] for r in rows}
    assert status["t.p50_ms"] == "improved"
    assert status["t.qps"] == "improved"


def test_missing_metric_is_a_regression_new_is_not():
    cur = _metrics()
    del cur["t.qps"]
    cur["extra"] = regress.metric(1.0)
    rows, n = regress.compare(cur, _metrics(), 1.0, 0.25)
    assert n == 1
    status = {r[0]: r[4] for r in rows}
    assert status["t.qps"] == "MISSING"
    assert status["extra"] == "new"


def test_floor_absorbs_sub_unit_jitter():
    # sub-ms latencies and near-empty range outputs jitter several x;
    # the floor turns their band absolute so they only gate at scale
    base = {"d.p50_ms": regress.metric(0.4),
            "d.avg_out": regress.metric(0.1, "higher", "struct")}
    cur = {"d.p50_ms": regress.metric(1.9),        # 4.75x but < 2ms
           "d.avg_out": regress.metric(0.0, "higher", "struct")}
    _, n = regress.compare(cur, base, 1.0, 0.25)
    assert n == 0
    cur["d.p50_ms"]["value"] = 40.0                # past floor * band
    _, n = regress.compare(cur, base, 1.0, 0.25)
    assert n == 1


def test_inject_degrades_time_metrics_only():
    inj = regress.inject(_metrics(), 2.0)
    assert inj["t.p50_ms"]["value"] == 20.0        # lower-better: *2
    assert inj["t.qps"]["value"] == 250.0          # higher-better: /2
    assert inj["s.bytes"]["value"] == 100_000      # struct untouched


# -- committed-baseline validation -----------------------------------------

def test_committed_baselines_validate():
    assert regress.check_baselines() == []


def test_baselines_carry_fused_frontier_metrics():
    """The perf gate must see the fused frontier kernel (PR 9): the
    roofline baseline carries the tile sweep (chosen defaults + per-tile
    cells) and both knn cells (auto -> fused vs pinned chunked), and the
    serve trace's captured plan costs include a pallas-frontier
    signature. check_baselines enforces the same shape — a baseline
    regenerated without the new metrics fails the gate."""
    import os

    with open(os.path.join(regress.RESULTS_DIR, "roofline.json")) as f:
        roof = json.load(f)
    sweep = roof["block_sweep"]
    assert sweep["cells"], "tile sweep cells missing"
    assert {"block_q", "block_p"} <= set(sweep["chosen"])
    for kind, row in roof["results"].items():
        assert "knn" in row and "knn_chunked" in row, kind
        assert row["knn"]["plan_sig"].startswith("knn.")
        assert "pallas-frontier" in row["knn"]["plan_sig"], (
            "auto no longer routes the fused kernel at roofline scale")

    with open(os.path.join(regress.RESULTS_DIR,
                           "serve_trace.json")) as f:
        trace = json.load(f)
    assert any("pallas-frontier" in sig
               for r in trace["results"].values()
               for sig in r["cost_model"].get("plan_costs", {}))


def test_truncated_baseline_is_flagged(tmp_path):
    for name in ("serve_latency", "fig4_knn", "fig5_range",
                 "fig10_batch", "roofline", "serve_trace"):
        (tmp_path / f"{name}.json").write_text("{}")
    problems = regress.check_baselines(str(tmp_path))
    assert len(problems) == 6


# -- CLI round-trip on fake suites -----------------------------------------

@pytest.fixture
def fake_suite(monkeypatch):
    state = {"runs": 0}

    def suite(verbose):
        state["runs"] += 1
        return _metrics()

    monkeypatch.setattr(regress, "SUITES", {"fake": suite})
    return state


def test_cli_update_then_gate_then_replay(fake_suite, tmp_path,
                                          monkeypatch, capsys):
    # committed-baseline validation looks at results/ — point it at a
    # valid tree (the repo's own) via cwd; tmp files hold the rest
    base = tmp_path / "base.json"
    snap = tmp_path / "snap.json"
    assert regress.main(["--suites", "fake", "--update", "--quiet",
                         "--baseline", str(base)]) == 0
    assert json.loads(base.read_text())["metrics"]["t.p50_ms"][
        "value"] == 10.0
    assert fake_suite["runs"] == 1

    # clean gate run: exit 0, snapshot written with the comparison
    assert regress.main(["--suites", "fake", "--baseline", str(base),
                         "--snapshot", str(snap), "--quiet"]) == 0
    payload = json.loads(snap.read_text())
    assert payload["regressed"] == 0
    assert {r["status"] for r in payload["rows"]} == {"ok"}
    assert fake_suite["runs"] == 2

    # replay re-compares without re-running suites; the injected 2x
    # regression must fail the gate (the CI self-test shape)
    assert regress.main(["--replay", str(snap), "--baseline", str(base),
                         "--inject-scale", "2", "--tol", "0.5",
                         "--no-snapshot", "--quiet"]) == 1
    assert fake_suite["runs"] == 2
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "FAIL" in out


def test_cli_errors(fake_suite, tmp_path):
    assert regress.main(["--suites", "nope", "--no-snapshot"]) == 2
    assert regress.main(["--suites", "fake", "--baseline",
                         str(tmp_path / "absent.json"),
                         "--no-snapshot", "--quiet"]) == 2
    assert regress.main(["--replay", str(tmp_path / "absent.json"),
                         "--baseline", str(tmp_path / "absent.json"),
                         "--no-snapshot"]) == 2
