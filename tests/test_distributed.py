"""Distributed-index tests on the CI-simulated mesh (subprocess).

The forced host device count must be staged before jax initializes, so
the actual work runs in a child process via
``helpers.run_on_simulated_mesh``; one child covers the full lifecycle
to amortize compile time. The 8-device lifecycle is fast-tier mesh
smoke (it exercises the full shard_map exchange); only the
multi-host-scale sweep stays ``slow``.
"""

from __future__ import annotations

import pytest

from helpers import run_on_simulated_mesh

SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed as D
from repro.data import points as gen

key = jax.random.PRNGKey(0)
pts = gen.uniform(key, 4096, 2)
idx = D.build(pts, mesh, phi=8)
assert int(idx.dropped) == 0
assert int(D.size(idx)) == 4096

newp = gen.uniform(jax.random.PRNGKey(1), 1024, 2)
idx = D.insert(idx, newp, mesh)
assert int(idx.dropped) == 0
assert int(D.size(idx)) == 5120

idx2 = D.delete(idx, pts[:1024], mesh)
assert int(D.size(idx2)) == 4096, int(D.size(idx2))

# exact kNN vs brute force
qs = gen.uniform(jax.random.PRNGKey(2), 24, 2)
d2, bp, ok = D.knn(idx, qs, 5, mesh)
allp = jnp.concatenate([pts, newp]).astype(jnp.float32)
for i in range(24):
    diff = allp - qs[i].astype(jnp.float32)
    bf = jnp.sort(jnp.sum(diff * diff, -1))[:5]
    assert np.allclose(np.sort(np.asarray(d2[i])), np.asarray(bf)), i

# exact range count
lo, hi = gen.query_boxes(jax.random.PRNGKey(3), 8, 2, gen.DEFAULT_HI // 8)
cnt, trunc = D.range_count(idx, lo, hi, mesh, max_rows=2048)
for i in range(8):
    bf = int(jnp.sum(jnp.all((allp >= lo[i]) & (allp <= hi[i]), -1)))
    assert int(cnt[i]) == bf, (i, int(cnt[i]), bf)

# splitter balance: uniform data must spread over every shard (the
# quantile sample must not be polluted by pad sentinels)
sizes = np.asarray(D.shard_sizes(idx))
assert sizes.min() > 0, sizes
assert sizes.sum() == int(D.size(idx))

# skewed routing (sweepline): slab overflow is *detected*, and a larger
# slack absorbs it
sw = gen.sweepline(jax.random.PRNGKey(4), 4096, 2)
idx3 = D.build(sw, mesh, phi=8, slack=8.0)
assert int(idx3.dropped) == 0
# the skewed *stream*: one batch lands in few shards
batch = sw[:512]
idx4 = D.insert(idx3, batch, mesh, slack=8.0)
tight = D.insert(idx3, batch, mesh, slack=0.25)
assert int(idx4.dropped) == 0
assert int(tight.dropped) > 0   # under-provisioned slab is reported

print("DISTRIBUTED_OK")
"""


def test_distributed_index_lifecycle():
    run_on_simulated_mesh(SCRIPT, 8, timeout_base_s=560,
                          expect="DISTRIBUTED_OK")


_SCALE_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed as D
from repro.data import points as gen

pts = gen.uniform(jax.random.PRNGKey(0), 1 << 16, 2)
idx = D.build(pts, mesh, phi=32)
assert int(idx.dropped) == 0
assert int(D.size(idx)) == 1 << 16
idx = D.insert(idx, gen.uniform(jax.random.PRNGKey(1), 1 << 14, 2), mesh)
assert int(idx.dropped) == 0
qs = gen.uniform(jax.random.PRNGKey(2), 8, 2)
d2, bp, ok = D.knn(idx, qs, 10, mesh)
allp = jnp.concatenate(
    [pts, gen.uniform(jax.random.PRNGKey(1), 1 << 14, 2)]
).astype(jnp.float32)
for i in range(8):
    diff = allp - qs[i].astype(jnp.float32)
    bf = jnp.sort(jnp.sum(diff * diff, -1))[:10]
    assert np.allclose(np.sort(np.asarray(d2[i])), np.asarray(bf)), i
print("SCALE_OK")
"""


@pytest.mark.slow  # 32-way shard_map at 64K points: multi-host-scale
def test_distributed_index_scale_32shards():
    run_on_simulated_mesh(_SCALE_SCRIPT, 32, timeout_base_s=1200,
                          expect="SCALE_OK")
