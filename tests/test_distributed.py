"""Distributed-index tests (8 forced host devices, subprocess).

The forced device count must be set before jax initializes, so the
actual work runs in a child process; one child covers the full
lifecycle to amortize compile time."""

from __future__ import annotations

import subprocess
import sys

import pytest

from helpers import scaled_timeout

pytestmark = pytest.mark.slow  # 8-device shard_map compile exceeds fast tier

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed as D
from repro.data import points as gen

mesh = jax.make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)
pts = gen.uniform(key, 4096, 2)
idx = D.build(pts, mesh, phi=8)
assert int(idx.dropped) == 0
assert int(D.size(idx)) == 4096

newp = gen.uniform(jax.random.PRNGKey(1), 1024, 2)
idx = D.insert(idx, newp, mesh)
assert int(idx.dropped) == 0
assert int(D.size(idx)) == 5120

idx2 = D.delete(idx, pts[:1024], mesh)
assert int(D.size(idx2)) == 4096, int(D.size(idx2))

# exact kNN vs brute force
qs = gen.uniform(jax.random.PRNGKey(2), 24, 2)
d2, bp, ok = D.knn(idx, qs, 5, mesh)
allp = jnp.concatenate([pts, newp]).astype(jnp.float32)
for i in range(24):
    diff = allp - qs[i].astype(jnp.float32)
    bf = jnp.sort(jnp.sum(diff * diff, -1))[:5]
    assert np.allclose(np.sort(np.asarray(d2[i])), np.asarray(bf)), i

# exact range count
lo, hi = gen.query_boxes(jax.random.PRNGKey(3), 8, 2, gen.DEFAULT_HI // 8)
cnt, trunc = D.range_count(idx, lo, hi, mesh, max_rows=2048)
for i in range(8):
    bf = int(jnp.sum(jnp.all((allp >= lo[i]) & (allp <= hi[i]), -1)))
    assert int(cnt[i]) == bf, (i, int(cnt[i]), bf)

# skewed routing (sweepline): slab overflow is *detected*, and a larger
# slack absorbs it
sw = gen.sweepline(jax.random.PRNGKey(4), 4096, 2)
idx3 = D.build(sw, mesh, phi=8, slack=8.0)
assert int(idx3.dropped) == 0
# the skewed *stream*: one batch lands in few shards
batch = sw[:512]
idx4 = D.insert(idx3, batch, mesh, slack=8.0)
tight = D.insert(idx3, batch, mesh, slack=0.25)
assert int(idx4.dropped) == 0
assert int(tight.dropped) > 0   # under-provisioned slab is reported

print("DISTRIBUTED_OK")
"""


def test_distributed_index_lifecycle():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=scaled_timeout(560),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert "DISTRIBUTED_OK" in out.stdout, out.stdout + out.stderr
