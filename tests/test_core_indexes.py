"""Exactness of P-Orth and SPaC trees against brute-force oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import porth, queries, spac


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------

def brute_knn(points, q, k):
    d2 = ((points.astype(np.float64) - q.astype(np.float64)) ** 2).sum(-1)
    idx = np.argsort(d2, kind="stable")[:k]
    return np.sort(d2[idx])


def brute_range_count(points, lo, hi):
    return int(np.all((points >= lo) & (points <= hi), axis=-1).sum())


def gen_points(rng, n, dim, dist="uniform", lo=0, hi=1 << 20):
    if dist == "uniform":
        return rng.integers(lo, hi, size=(n, dim)).astype(np.int32)
    if dist == "varden":  # clustered random walk with restarts
        pts = np.zeros((n, dim), np.int64)
        cur = rng.integers(lo, hi, size=dim)
        for i in range(n):
            if rng.random() < 0.01:
                cur = rng.integers(lo, hi, size=dim)
            cur = np.clip(cur + rng.integers(-50, 51, size=dim), lo, hi - 1)
            pts[i] = cur
        return pts.astype(np.int32)
    if dist == "sweepline":
        p = rng.integers(lo, hi, size=(n, dim))
        return p[np.argsort(p[:, 0])].astype(np.int32)
    raise ValueError(dist)


def check_queries(view, pts_np, rng, k=8, n_q=40, seed_pts=True):
    """Compare engine results against brute force on random queries."""
    dim = pts_np.shape[1]
    qs = gen_points(rng, n_q, dim).astype(np.int32)
    if seed_pts and len(pts_np):  # half the queries ON data points (InD)
        qs[: n_q // 2] = pts_np[rng.integers(0, len(pts_np), n_q // 2)]
    kk = min(k, max(len(pts_np), 1))
    d2, ids = queries.knn(view, jnp.asarray(qs), kk, chunk=4)
    for i in range(n_q):
        want = brute_knn(pts_np, qs[i], kk)
        got = np.asarray(d2[i][: len(want)], np.float64)
        np.testing.assert_allclose(got, want, rtol=1e-6,
                                   err_msg=f"kNN mismatch q={qs[i]}")
    # range queries
    lo = qs
    hi = qs + rng.integers(1, 1 << 18, size=qs.shape).astype(np.int32)
    cnt, trunc = queries.range_count(view, jnp.asarray(lo), jnp.asarray(hi),
                                     max_rows=512)
    assert not np.any(np.asarray(trunc)), "increase max_rows in test"
    for i in range(n_q):
        assert int(cnt[i]) == brute_range_count(pts_np, lo[i], hi[i]), \
            f"range mismatch box={lo[i]},{hi[i]}"


def live_points(view):
    ok = np.asarray(view.valid & view.active[:, None]).reshape(-1)
    pts = np.asarray(view.pts).reshape(-1, view.pts.shape[-1])
    return pts[ok]


ROOT_LO = jnp.zeros(2, jnp.int32)
ROOT_HI = jnp.full(2, 1 << 20, jnp.int32)


def make_index(kind, pts, phi=8):
    if kind == "porth":
        return porth.build(jnp.asarray(pts), ROOT_LO[: pts.shape[1]],
                           jnp.full(pts.shape[1], 1 << 20, jnp.int32),
                           phi=phi, lam=3 if pts.shape[1] == 2 else 2,
                           rounds=5)
    curve = {"spac_h": "hilbert", "spac_z": "morton"}[kind]
    return spac.build(jnp.asarray(pts), phi=phi, curve=curve,
                      coord_bits=20)


def ins_with_headroom(kind, t, extra):
    """Production pattern: grow capacity before a batch insert if needed."""
    mod = porth if kind == "porth" else spac
    need = int(t.num_rows) + len(extra) + 8
    if t.capacity_rows < need:
        t = mod.grow(t, need)
    return mod.insert(t, jnp.asarray(extra),
                      max_overflow_rows=min(128, t.capacity_rows))


INDEX_KINDS = ["porth", "spac_h", "spac_z"]
DISTS = ["uniform", "varden", "sweepline"]


@pytest.mark.parametrize("kind", INDEX_KINDS)
@pytest.mark.parametrize("dist", DISTS)
def test_build_and_query(kind, dist):
    rng = np.random.default_rng(42)
    pts = gen_points(rng, 2000, 2, dist)
    t = make_index(kind, pts)
    assert not bool(t.overflowed)
    assert int(t.size) == len(pts)
    # multiset of stored points survives
    np.testing.assert_array_equal(
        np.sort(live_points(t.view()), axis=0), np.sort(pts, axis=0))
    check_queries(t.view(), pts, rng)


@pytest.mark.parametrize("kind", INDEX_KINDS)
@pytest.mark.parametrize("dist", ["uniform", "varden"])
def test_batch_insert(kind, dist):
    rng = np.random.default_rng(7)
    pts = gen_points(rng, 1500, 2, dist)
    extra = gen_points(rng, 600, 2, dist)
    t = make_index(kind, pts)
    t = ins_with_headroom(kind, t, extra)
    assert not bool(t.overflowed)
    allp = np.concatenate([pts, extra])
    assert int(t.size) == len(allp)
    np.testing.assert_array_equal(
        np.sort(live_points(t.view()), axis=0), np.sort(allp, axis=0))
    check_queries(t.view(), allp, rng)


@pytest.mark.parametrize("kind", INDEX_KINDS)
def test_batch_delete(kind):
    rng = np.random.default_rng(3)
    pts = gen_points(rng, 1500, 2, "uniform")
    t = make_index(kind, pts)
    sel = rng.permutation(len(pts))[:500]
    dels = pts[sel]
    if kind == "porth":
        t = porth.delete(t, jnp.asarray(dels))
    else:
        t = spac.delete(t, jnp.asarray(dels))
    keep = np.delete(pts, sel, axis=0)
    assert int(t.size) == len(keep)
    np.testing.assert_array_equal(
        np.sort(live_points(t.view()), axis=0), np.sort(keep, axis=0))
    check_queries(t.view(), keep, rng)


@pytest.mark.parametrize("kind", INDEX_KINDS)
def test_duplicates_multiset_semantics(kind):
    rng = np.random.default_rng(5)
    base = gen_points(rng, 50, 2, "uniform")
    pts = np.repeat(base, 4, axis=0)  # every point 4 times
    t = make_index(kind, pts)
    assert int(t.size) == 200
    # delete two copies of each of the first 10 points
    dels = np.repeat(base[:10], 2, axis=0)
    t = (porth.delete if kind == "porth" else spac.delete)(
        t, jnp.asarray(dels))
    assert int(t.size) == 180
    live = live_points(t.view())
    for b in base[:10]:
        assert (live == b).all(axis=1).sum() == 2
    check_queries(t.view(), live, rng)


@pytest.mark.parametrize("kind", INDEX_KINDS)
def test_incremental_equals_bulk(kind):
    """insert(build(P), Q) answers every query identically to build(P u Q)."""
    rng = np.random.default_rng(11)
    pts = gen_points(rng, 1200, 2, "uniform")
    t = make_index(kind, pts[:600])
    for s in range(600, 1200, 200):
        t = ins_with_headroom(kind, t, pts[s:s + 200])
    assert not bool(t.overflowed)
    assert int(t.size) == 1200
    check_queries(t.view(), pts, rng)


def test_insert_into_empty_tree():
    rng = np.random.default_rng(13)
    pts = gen_points(rng, 300, 2, "uniform")
    for kind in INDEX_KINDS:
        t = make_index(kind, pts)
        dele = porth.delete if kind == "porth" else spac.delete
        t = dele(t, jnp.asarray(pts))  # empty it
        assert int(t.size) == 0
        t = ins_with_headroom(kind, t, pts[:100])
        assert int(t.size) == 100, kind
        check_queries(t.view(), pts[:100], rng)


def test_porth_3d():
    rng = np.random.default_rng(17)
    pts = gen_points(rng, 1000, 3, "uniform")
    t = porth.build(jnp.asarray(pts), jnp.zeros(3, jnp.int32),
                    jnp.full(3, 1 << 20, jnp.int32), phi=8, lam=2, rounds=5)
    assert int(t.size) == 1000
    check_queries(t.view(), pts, rng)


def test_spac_3d():
    rng = np.random.default_rng(19)
    pts = gen_points(rng, 1000, 3, "varden")
    t = spac.build(jnp.asarray(pts), phi=8, curve="hilbert", bits=10,
                   coord_bits=20)
    assert int(t.size) == 1000
    check_queries(t.view(), pts, rng)


def test_porth_float_coords():
    """The paper's applicability claim: P-Orth works on float coordinates."""
    rng = np.random.default_rng(23)
    pts = rng.random((800, 2)).astype(np.float32)
    t = porth.build(jnp.asarray(pts), jnp.zeros(2, jnp.float32),
                    jnp.ones(2, jnp.float32), phi=8)
    assert int(t.size) == 800
    qs = rng.random((20, 2)).astype(np.float32)
    d2, ids = queries.knn(t.view(), jnp.asarray(qs), 5, chunk=4)
    for i in range(20):
        want = brute_knn(pts, qs[i], 5)
        np.testing.assert_allclose(np.asarray(d2[i], np.float64), want,
                                   rtol=1e-4)


def test_spac_unsorted_flag_lifecycle():
    """Partial-order relaxation: appends mark rows unsorted; splits restore."""
    rng = np.random.default_rng(29)
    pts = gen_points(rng, 400, 2, "uniform")
    t = spac.build(jnp.asarray(pts), phi=8, coord_bits=20)
    assert not bool(jnp.any(t.unsorted))
    t2 = spac.insert(t, jnp.asarray(gen_points(rng, 5, 2, "uniform")))
    assert bool(jnp.any(t2.unsorted & t2.active))
