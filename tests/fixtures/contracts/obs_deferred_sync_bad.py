# lint-as: src/repro/obs/record.py
"""Violates obs-deferred-sync: instrumentation helpers read device
values inline (a hidden sync on whatever path they instrument) instead
of attaching them for the barrier drain."""
import jax


class Span:
    def set_rows(self, value):
        self.args["rows"] = float(jax.block_until_ready(value))
        return self


class Recorder:
    def count_now(self, name, value):
        self.counters[name] = self.counters.get(name, 0) + value.item()

    def live_bytes(self):
        # allocator query outside the resolve drain: a mid-dispatch
        # device round-trip hiding inside "just accounting"
        return sum(d.memory_stats()["bytes_in_use"]
                   for d in jax.local_devices())
