# lint-as: src/repro/obs/record.py
"""Violates obs-deferred-sync: instrumentation helpers read device
values inline (a hidden sync on whatever path they instrument) instead
of attaching them for the barrier drain."""
import jax


class Span:
    def set_rows(self, value):
        self.args["rows"] = float(jax.block_until_ready(value))
        return self


class Recorder:
    def count_now(self, name, value):
        self.counters[name] = self.counters.get(name, 0) + value.item()
