# lint-as: src/repro/launch/fixture_tool.py
"""Violates uncached-jit: one jit built per call in a function body,
one per constructed object via a nested decorated def."""
import jax


def make_runner(fn):
    return jax.jit(fn)


class Engine:
    def __init__(self, cfg):
        @jax.jit
        def _go(x):
            return x + cfg.scale

        self._go = _go
