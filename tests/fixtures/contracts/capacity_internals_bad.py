# lint-as: src/repro/bench/fixture_tool.py
"""Violates capacity-internals: a bench tool drives the capacity ladder
by hand instead of letting the facade recover."""


def force_room(idx, batch):
    if idx.tree.overflowed:
        idx = idx.grow(2 * idx.capacity_rows)
    return idx.insert(batch)
