# lint-as: src/repro/serving/server.py
"""Clean: insert() keeps the row count on device and defers the read
to the next commit barrier."""
import jax.numpy as jnp


class SpatialServer:
    def insert(self, pts, mask=None):
        self._deferred_points.append(jnp.sum(mask, dtype=jnp.int32))
        return self._publish(pts)
