# lint-as: src/repro/serving/server.py
"""Clean: serving/server.py holds the one sanctioned exception — the
deferred sticky-overflow read at its sync points (and nothing else)."""


def commit_check(tree):
    return bool(getattr(tree, "overflowed", False))
