# lint-as: src/repro/core/fixture_dist.py
"""Clean: the region calls the unjitted _impl spelling; the jitted
alias exists at module level for single-device callers."""
import jax
from jax.experimental.shard_map import shard_map


def kernel_impl(x, *, k=2):
    return x * k


kernel = jax.jit(kernel_impl, static_argnames=("k",))


def update(points, mesh, spec):
    def local(p):
        return kernel_impl(p)
    return shard_map(local, mesh=mesh, in_specs=spec,
                     out_specs=spec)(points)
