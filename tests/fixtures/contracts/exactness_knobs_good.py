# lint-as: src/repro/core/engine.py
"""Clean: the same knob-twiddling code is *allowed* here — the virtual
path is the engine layer, which owns buffer sizing and truncation."""


def _escalate(dispatch, index, lo, hi, max_rows):
    res = dispatch.range_count(index, lo, hi, max_rows=max_rows)
    while res.truncated:
        max_rows *= 2
        res = dispatch.range_count(index, lo, hi, max_rows=max_rows)
    return res.count
