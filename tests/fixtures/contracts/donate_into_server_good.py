# lint-as: src/repro/bench/fixture_serve.py
"""Clean: no donation reaches the server; donate=True is fine for a
throwaway index that never gets wrapped."""
from repro.core import make_index
from repro.serving import SpatialServer


def serve(pts):
    idx = make_index("spac-h", pts)
    return SpatialServer(idx, window=4)


def bulk_load_only(pts, batch):
    idx = make_index("spac-h", pts, donate=True)
    return idx.insert(batch)
