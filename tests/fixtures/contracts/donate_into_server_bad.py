# lint-as: src/repro/bench/fixture_serve.py
"""Violates donate-into-server three ways: a donated index flowing in
by name, one constructed inline, and SpatialServer.build(donate=True).
"""
from repro.core import make_index
from repro.serving import SpatialServer


def by_name(pts):
    idx = make_index("spac-h", pts, donate=True)
    return SpatialServer(idx, window=4)


def inline(pts):
    return SpatialServer(make_index("spac-h", pts, donate=True))


def via_build(pts):
    return SpatialServer.build("spac-h", pts, donate=True)
