# lint-as: src/repro/serving/server.py
"""Violates host-sync-in-dispatch: insert() blocks on the device and
pulls the mask to host before returning."""
import jax
import numpy as np


class SpatialServer:
    def insert(self, pts, mask=None):
        jax.block_until_ready(pts)
        rows = int(np.asarray(mask).sum())
        self.stats["update_points"] += rows
        return self._publish(pts)
