# lint-as: src/repro/core/fixture_dist.py
"""Violates jit-in-shard-map: the shard_map region calls a jitted
callee (and constructs a jit inline)."""
import functools

import jax
from jax.experimental.shard_map import shard_map


@functools.partial(jax.jit, static_argnames=("k",))
def kernel(x, *, k=2):
    return x * k


def update(points, mesh, spec):
    def local(p):
        q = jax.jit(lambda a: a + 1)(p)   # jit built inside the region
        return kernel(q)                  # jitted callee inside region
    return shard_map(local, mesh=mesh, in_specs=spec,
                     out_specs=spec)(points)
