# lint-as: src/repro/launch/fixture_tool.py
"""Clean: module-level jit, plus the sanctioned lru_cache closure
factory (the _update_closure / query-plan pattern)."""
import functools

import jax


@jax.jit
def step(x):
    return x * 2


@functools.lru_cache(maxsize=None)
def closures(scale):
    def go(x):
        return x + scale

    return jax.jit(go)
