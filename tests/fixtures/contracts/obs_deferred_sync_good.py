# lint-as: src/repro/obs/record.py
"""Clean: device values are attached on the hot path and read only
inside ``resolve`` — the one sanctioned barrier drain. Memory
accounting outside the drain sticks to ``nbytes`` metadata; the
allocator snapshot (``memory_stats``) runs inside ``resolve`` only."""
import jax


def tree_bytes(tree):
    # nbytes is shape/dtype arithmetic — no device read, dispatch-safe
    return sum(getattr(leaf, "nbytes", 0)
               for leaf in jax.tree_util.tree_leaves(tree))


class Recorder:
    def add_deferred(self, name, value):
        self._pending.append((name, None, value))

    def gauge_bytes(self, name, tree):
        self.gauge(name, tree_bytes(tree))

    def resolve(self):
        for dev in jax.local_devices():
            stats = dev.memory_stats()          # sanctioned: barrier
            if stats:
                self.gauge("backend.mem.bytes", stats["bytes_in_use"])
        pending, self._pending = self._pending, []
        for name, _, value in pending:
            self.count(name, float(jax.block_until_ready(value)))
        return len(pending)
