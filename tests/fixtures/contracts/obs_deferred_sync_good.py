# lint-as: src/repro/obs/record.py
"""Clean: device values are attached on the hot path and read only
inside ``resolve`` — the one sanctioned barrier drain."""
import jax


class Recorder:
    def add_deferred(self, name, value):
        self._pending.append((name, None, value))

    def resolve(self):
        pending, self._pending = self._pending, []
        for name, _, value in pending:
            self.count(name, float(jax.block_until_ready(value)))
        return len(pending)
