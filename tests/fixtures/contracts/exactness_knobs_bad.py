# lint-as: src/repro/bench/fixture_driver.py
"""Violates exactness-knobs: a caller outside the engine layer sizes
the answer buffer and inspects truncation itself."""


def count_in_box(dispatch, index, lo, hi):
    res = dispatch.range_count(index, lo, hi, max_rows=128)
    if res.truncated:
        raise RuntimeError("buffer too small")
    return res.count
