"""Per-kernel allclose vs the pure-jnp oracle, across shape/dtype sweeps.

Kernels execute in interpret mode (CPU container; TPU is the lowering
target — see DESIGN.md)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bbox import ops as bbox_ops
from repro.kernels.flash_attn import ops as fa_ops
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.knn import ops as knn_ops
from repro.kernels.morton import ops as morton_ops
from repro.kernels.sieve import ops as sieve_ops
from repro.kernels.sieve.ref import bucket_ids_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,d", [
    (1, 2, 2, 64, 64, 32),     # MHA square
    (2, 4, 2, 64, 64, 32),     # GQA
    (1, 4, 1, 32, 128, 16),    # MQA decode-ish (suffix queries)
    (1, 2, 2, 48, 80, 32),     # ragged (non-multiple of block)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Hq, Hkv, Sq, Skv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, d), dtype)
    got = fa_ops.attention(q, k, v, causal=True, impl="interpret",
                           block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 96, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 96, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 96, 32), jnp.float32)
    got = fa_ops.attention(q, k, v, causal=True, window=window,
                           impl="interpret", block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32)
    got = fa_ops.attention(q, k, v, causal=False, impl="interpret",
                           block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# morton
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,bits,n", [(2, 15, 1000), (2, 16, 64),
                                        (3, 10, 513)])
def test_morton_kernel(dim, bits, n):
    rng = np.random.default_rng(0)
    pts = rng.integers(0, 1 << 20, size=(n, dim)).astype(np.int32)
    got = morton_ops.morton_encode(jnp.asarray(pts), bits=bits,
                                   coord_bits=20, impl="interpret")
    want = morton_ops.morton_encode(jnp.asarray(pts), bits=bits,
                                    coord_bits=20, impl="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# sieve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,lam,n,dtype", [
    (2, 3, 2048, jnp.int32), (2, 3, 1000, jnp.float32),
    (3, 2, 513, jnp.int32), (2, 2, 4096, jnp.float32)])
def test_sieve_histogram_kernel(dim, lam, n, dtype):
    rng = np.random.default_rng(1)
    if dtype == jnp.float32:
        pts = rng.random((n, dim)).astype(np.float32)
        lo = np.zeros((n, dim), np.float32)
        hi = np.ones((n, dim), np.float32)
    else:
        pts = rng.integers(0, 1 << 20, size=(n, dim)).astype(np.int32)
        lo = np.zeros((n, dim), np.int32)
        hi = np.full((n, dim), 1 << 20, np.int32)
    got = sieve_ops.sieve_histogram(jnp.asarray(pts), jnp.asarray(lo),
                                    jnp.asarray(hi), lam=lam, block_n=256,
                                    impl="interpret")
    want = sieve_ops.sieve_histogram(jnp.asarray(pts), jnp.asarray(lo),
                                     jnp.asarray(hi), lam=lam, block_n=256,
                                     impl="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sieve_partition_is_stable_counting_sort():
    rng = np.random.default_rng(2)
    n = 3000
    pts = rng.integers(0, 1 << 20, size=(n, 2)).astype(np.int32)
    lo = jnp.zeros((n, 2), jnp.int32)
    hi = jnp.full((n, 2), 1 << 20, jnp.int32)
    dest, bucket, offsets = sieve_ops.sieve_partition(
        jnp.asarray(pts), lo, hi, lam=3, block_n=512, impl="ref")
    dest, bucket = np.asarray(dest), np.asarray(bucket)
    # dest is a permutation
    assert len(np.unique(dest)) == n
    # equal buckets keep input order (stability) and are contiguous
    out_bucket = np.empty(n, np.int32)
    out_src = np.empty(n, np.int64)
    out_bucket[dest] = bucket
    out_src[dest] = np.arange(n)
    assert (np.diff(out_bucket) >= 0).all()
    for b in np.unique(bucket):
        srcs = out_src[out_bucket == b]
        assert (np.diff(srcs) > 0).all()
    # offsets match bucket boundaries
    want_off = np.searchsorted(out_bucket, np.arange(64))
    np.testing.assert_array_equal(np.asarray(offsets), want_off)


def test_sieve_buckets_match_porth_convention():
    """The sieve kernel's comparison-based buckets equal Morton bits."""
    rng = np.random.default_rng(3)
    n = 512
    pts = rng.integers(0, 1 << 6, size=(n, 2)).astype(np.int32)
    lo = jnp.zeros((n, 2), jnp.int32)
    hi = jnp.full((n, 2), 1 << 6, jnp.int32)
    got = np.asarray(bucket_ids_ref(jnp.asarray(pts), lo, hi, lam=3))
    from repro.core import sfc
    want = np.asarray(sfc.morton_encode(jnp.asarray(pts).astype(jnp.uint32),
                                        6)) >> 6  # top 3 levels = 6 bits
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# knn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Q,N,dim,k", [(64, 500, 2, 8), (33, 1024, 3, 4),
                                       (128, 256, 2, 16)])
def test_knn_kernel(Q, N, dim, k):
    rng = np.random.default_rng(4)
    qs = rng.random((Q, dim)).astype(np.float32)
    ps = rng.random((N, dim)).astype(np.float32)
    ok = rng.random(N) > 0.1
    d_got, i_got = knn_ops.knn_bruteforce(
        jnp.asarray(qs), jnp.asarray(ps), jnp.asarray(ok), k=k,
        block_q=32, block_p=128, impl="pallas-interpret")
    d_want, i_want = knn_ops.knn_bruteforce(
        jnp.asarray(qs), jnp.asarray(ps), jnp.asarray(ok), k=k, impl="ref")
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want),
                               rtol=1e-4, atol=1e-5)


def test_knn_kernel_rejects_legacy_interpret_alias():
    """One canonical spelling across layers: "interpret" must fail loudly
    at the kernel boundary (the engine rejects it too)."""
    q = jnp.zeros((4, 2), jnp.float32)
    p = jnp.zeros((8, 2), jnp.float32)
    ok = jnp.ones(8, bool)
    with pytest.raises(ValueError, match="pallas-interpret"):
        knn_ops.knn_bruteforce_impl(q, p, ok, k=2, impl="interpret")
    with pytest.raises(ValueError, match="unknown knn kernel impl"):
        knn_ops.knn_bruteforce_impl(q, p, ok, k=2, impl="mxu")


# ---------------------------------------------------------------------------
# fused frontier knn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,C,dim,Q,k,bq,bp", [
    (37, 16, 2, 33, 8, 8, 64),      # ragged everything
    (64, 8, 3, 16, 4, 16, 128),     # 3-d, whole blocks
    (5, 4, 2, 7, 32, 8, 8),         # k > live points
])
def test_frontier_kernel_interpret_matches_ref(R, C, dim, Q, k, bq, bp):
    """Interpret-mode fused kernel is bit-identical to its jnp mirror:
    same prep, same tile expressions, same visit prefix."""
    from repro.kernels.frontier import knn_frontier_impl

    rng = np.random.default_rng(11)
    pts = jnp.asarray(rng.integers(0, 1 << 10, (R, C, dim)), jnp.int32)
    valid = jnp.asarray(rng.random((R, C)) > 0.2)
    active = jnp.asarray(rng.random(R) > 0.1)
    lo = jnp.where(valid[..., None], pts, jnp.int32(1 << 30)).min(axis=1)
    hi = jnp.where(valid[..., None], pts, jnp.int32(-1)).max(axis=1)
    q = jnp.asarray(rng.integers(0, 1 << 10, (Q, dim)), jnp.int32)

    args = (pts, valid, active, lo, hi, q)
    d_ref, i_ref = knn_frontier_impl(*args, k=k, impl="ref",
                                     block_q=bq, block_p=bp)
    d_int, i_int = knn_frontier_impl(*args, k=k, impl="pallas-interpret",
                                     block_q=bq, block_p=bp)
    np.testing.assert_array_equal(np.asarray(d_int), np.asarray(d_ref))
    np.testing.assert_array_equal(np.asarray(i_int), np.asarray(i_ref))


def test_frontier_kernel_rejects_legacy_interpret_alias():
    from repro.kernels.frontier import knn_frontier_impl

    z = jnp.zeros((4, 4, 2), jnp.int32)
    with pytest.raises(ValueError, match="pallas-interpret"):
        knn_frontier_impl(z, jnp.ones((4, 4), bool), jnp.ones(4, bool),
                          z[:, 0], z[:, 0], z[:, 0], k=2, impl="interpret")


# ---------------------------------------------------------------------------
# bbox
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,C,dim", [(100, 16, 2), (257, 64, 3)])
def test_bbox_kernel(R, C, dim):
    rng = np.random.default_rng(5)
    pts = rng.random((R, C, dim)).astype(np.float32)
    valid = rng.random((R, C)) > 0.3
    lo_g, hi_g = bbox_ops.row_bbox(jnp.asarray(pts), jnp.asarray(valid),
                                   block_r=64, impl="interpret")
    lo_w, hi_w = bbox_ops.row_bbox(jnp.asarray(pts), jnp.asarray(valid),
                                   impl="ref")
    np.testing.assert_allclose(np.asarray(lo_g), np.asarray(lo_w))
    np.testing.assert_allclose(np.asarray(hi_g), np.asarray(hi_w))
