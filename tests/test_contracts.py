"""Contract linter: fixture pairs per rule, pragma semantics, and the
tree-wide gate (``src``/``benchmarks``/``examples`` must lint clean).

The fixtures under ``tests/fixtures/contracts/`` carry a
``# lint-as: <virtual path>`` first line so path-scoped rules (engine
allowlist, serving dispatch scopes) can be exercised from here.
"""

import pathlib
import textwrap

import pytest

from repro.analysis import RULES, lint_paths, lint_sources
from repro.analysis.lint import main

REPO = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "contracts"

RULE_NAMES = [cls.name for cls in RULES]


def lint_fixture(name: str):
    path = FIXTURES / name
    return lint_sources({str(path): path.read_text()})


def lint_snippet(source: str, path: str = "src/repro/bench/snippet.py"):
    return lint_sources({path: textwrap.dedent(source)})


# -- fixture pairs ---------------------------------------------------------

@pytest.mark.parametrize("rule", RULE_NAMES)
def test_bad_fixture_violates_its_rule(rule):
    stem = rule.replace("-", "_")
    res = lint_fixture(f"{stem}_bad.py")
    hits = [d for d in res.diagnostics if d.rule == rule]
    assert hits, f"{stem}_bad.py should violate {rule}; got " \
                 f"{[d.render() for d in res.diagnostics]}"


@pytest.mark.parametrize("rule", RULE_NAMES)
def test_good_fixture_is_clean(rule):
    stem = rule.replace("-", "_")
    res = lint_fixture(f"{stem}_good.py")
    assert res.ok, "\n".join(d.render() for d in res.diagnostics)
    assert not res.suppressed, "good fixtures must be clean without pragmas"


def test_every_rule_has_a_fixture_pair():
    for rule in RULE_NAMES:
        stem = rule.replace("-", "_")
        assert (FIXTURES / f"{stem}_bad.py").is_file()
        assert (FIXTURES / f"{stem}_good.py").is_file()


def test_bad_fixtures_count_expected_violations():
    # the three donate shapes: by name, inline, via .build
    res = lint_fixture("donate_into_server_bad.py")
    assert len([d for d in res.diagnostics
                if d.rule == "donate-into-server"]) == 3
    # block_until_ready + np.asarray
    res = lint_fixture("host_sync_in_dispatch_bad.py")
    assert len([d for d in res.diagnostics
                if d.rule == "host-sync-in-dispatch"]) == 2
    # jit built in region + jitted callee in region
    res = lint_fixture("jit_in_shard_map_bad.py")
    assert len([d for d in res.diagnostics
                if d.rule == "jit-in-shard-map"]) == 2
    # block_until_ready + .item() + memory_stats outside resolve
    res = lint_fixture("obs_deferred_sync_bad.py")
    hits = [d for d in res.diagnostics if d.rule == "obs-deferred-sync"]
    assert len(hits) == 3
    assert any("memory_stats" in d.message for d in hits)


# -- pragma semantics ------------------------------------------------------

SNIPPET_WITH_KNOB = """\
def count(dispatch, index, lo, hi):
    res = dispatch.range_count(index, lo, hi, max_rows=128)
    return res.count
"""


def test_trailing_pragma_suppresses_one_rule_on_one_line():
    src = SNIPPET_WITH_KNOB.replace(
        "max_rows=128)",
        "max_rows=128)  # contract: allow[exactness-knobs] fixture")
    res = lint_snippet(src)
    assert res.ok
    assert [d.rule for d in res.suppressed] == ["exactness-knobs"]


def test_comment_line_pragma_targets_next_code_line():
    src = ("def count(dispatch, index, lo, hi):\n"
           "    # contract: allow[exactness-knobs] fixture\n"
           "    res = dispatch.range_count(index, lo, hi, max_rows=9)\n"
           "    return res.count\n")
    res = lint_snippet(src)
    assert res.ok and len(res.suppressed) == 1


def test_pragma_does_not_leak_to_other_lines_or_rules():
    # pragma on line 2 must not cover the same violation on line 3,
    # and an exactness pragma must not cover a capacity violation
    src = ("def f(dispatch, index, lo, hi, idx):\n"
           "    a = dispatch.range_count(index, lo, hi, max_rows=1)"
           "  # contract: allow[exactness-knobs] fixture\n"
           "    b = dispatch.range_count(index, lo, hi, max_rows=1)\n"
           "    return a, b, idx.capacity_rows"
           "  # contract: allow[exactness-knobs] wrong rule\n")
    res = lint_snippet(src)
    rules = sorted(d.rule for d in res.diagnostics)
    assert "exactness-knobs" in rules          # line 3 still flagged
    assert "capacity-internals" in rules       # wrong-rule pragma inert
    assert "unused-pragma" in rules            # ...and reported stale
    assert [d.rule for d in res.suppressed] == ["exactness-knobs"]


def test_unknown_rule_in_pragma_is_a_lint_error():
    src = SNIPPET_WITH_KNOB.replace(
        "max_rows=128)",
        "max_rows=128)  # contract: allow[exactness-nobs] typo")
    res = lint_snippet(src)
    assert any(d.rule == "bad-pragma" for d in res.diagnostics)


def test_unused_pragma_is_a_lint_error():
    res = lint_snippet("x = 1  # contract: allow[uncached-jit] stale\n")
    assert [d.rule for d in res.diagnostics] == ["unused-pragma"]


def test_pragma_in_string_literal_is_inert():
    res = lint_snippet('msg = "# contract: allow[not-a-rule]"\n')
    assert res.ok and not res.suppressed


def test_suppressions_counted_in_summary():
    src = SNIPPET_WITH_KNOB.replace(
        "max_rows=128)",
        "max_rows=128)  # contract: allow[exactness-knobs] fixture")
    res = lint_snippet(src)
    assert "1 suppressed" in res.summary()


# -- the tree-wide gate ----------------------------------------------------

LINTED = [str(REPO / p) for p in ("src", "benchmarks", "examples")]


def test_tree_lints_clean():
    res = lint_paths(LINTED)
    assert res.ok, "\n".join(d.render() for d in res.diagnostics)
    # the audit left justified escapes behind; they must stay counted
    assert res.suppressed, "expected audited # contract: allow pragmas"


def test_deleting_any_pragma_fails_the_lint():
    """Acceptance criterion: every pragma in the tree is load-bearing —
    removing it surfaces either its violation or unused-pragma."""
    import repro.analysis.lint as lint_mod
    from repro.analysis.pragmas import parse_pragmas

    checked = 0
    for path in lint_mod.discover(LINTED):
        text = pathlib.Path(path).read_text()
        lines = text.splitlines(keepends=True)
        for pragma in parse_pragmas(text):
            i = pragma.line - 1
            pruned = "".join(lines[:i] + lines[i + 1:])
            res = lint_sources({path: pruned})
            assert not res.ok, (
                f"{path}:{pragma.line}: pragma removable without "
                f"failing lint")
            checked += 1
    assert checked >= 7, f"expected >=7 audited pragmas, found {checked}"


# -- CLI -------------------------------------------------------------------

def test_cli_exit_codes_and_summary(capsys, tmp_path):
    assert main(LINTED) == 0
    out = capsys.readouterr().out
    assert "0 violation(s)" in out and "suppressed" in out

    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n\n"
                   "def f(g):\n"
                   "    return jax.jit(g)\n")
    assert main([str(bad)]) == 1
    assert "uncached-jit" in capsys.readouterr().out

    assert main([str(tmp_path / "nope")]) == 2
