"""Distributed serving on the CI-simulated 8-device mesh (subprocess).

The serving contract (tests/test_serving.py) re-verified when the
server's head index is a mesh-sharded ``DistributedIndex``:

* snapshot isolation + micro-batched answers bit-match the
  *single-device* answers for every mesh-capable backend, even with
  updates in flight behind the snapshot;
* the deferred capacity check replays from the committed base when a
  **shard** overflows (sticky per-shard ``overflowed`` / routing-slab
  ``dropped`` are only read at eviction/commit barriers);
* the batcher's pow2 coalescing keeps the retrace bound across the
  distributed exchange: warm repeat rounds compile nothing.

Each test runs in a child process via
``helpers.run_on_simulated_mesh`` (the forced host device count must
precede jax init); one child per test amortizes the 8-way compiles.
"""

from __future__ import annotations

from helpers import run_on_simulated_mesh

# -- (a) snapshot isolation + batcher bit-parity vs single-device -----------

_PARITY_SCRIPT = r"""
import jax, numpy as np
from repro.core import make_index
from repro.data import points as gen
from repro.serving.batcher import MicroBatcher
from repro.serving.server import SpatialServer

N, Q, K, B = 2048, 16, 5, 8
pts = np.asarray(gen.uniform(jax.random.PRNGKey(0), N, 2))
qs = np.asarray(gen.uniform(jax.random.PRNGKey(2), Q, 2))
lo, hi = gen.query_boxes(jax.random.PRNGKey(3), B, 2, gen.DEFAULT_HI // 8)
lo, hi = np.asarray(lo), np.asarray(hi)
newp = np.asarray(gen.uniform(jax.random.PRNGKey(4), 256, 2))

for kind in ("spac-h", "spac-z", "porth"):
    solo = make_index(kind, pts, phi=8)
    solo_d2, _ = solo.knn(qs, K)
    solo_d2 = np.asarray(solo_d2)
    solo_cnt = np.asarray(solo.range_count(lo, hi))

    srv = SpatialServer.build(kind, pts, mesh=mesh, phi=8, window=3)
    snap = srv.snapshot()
    bat = MicroBatcher(snap, max_batch=1024, max_delay_s=60.0)
    knn_tk = [bat.submit_knn(qs[i], K) for i in range(Q)]
    cnt_tk = [bat.submit_range_count(lo[i], hi[i]) for i in range(B)]
    # dispatch updates *after* the snapshot: answers below must still
    # come from the pre-update version (snapshot isolation)
    srv.insert(newp)
    srv.delete(pts[:256])
    for i, t in enumerate(knn_tk):
        d2, bp, ok = t.result()
        d2, bp = np.asarray(d2)[0], np.asarray(bp)[0]
        np.testing.assert_array_equal(d2, solo_d2[i]), (kind, i)
        # the returned neighbor coordinates reproduce the distances
        diff = bp.astype(np.float32) - qs[i].astype(np.float32)
        re_d2 = (diff * diff).sum(-1)
        assert np.allclose(re_d2[np.asarray(ok)[0]],
                           d2[np.asarray(ok)[0]]), (kind, i)
    for i, t in enumerate(cnt_tk):
        assert int(np.asarray(t.result())[0]) == int(solo_cnt[i]), (kind, i)
    srv.commit()
    assert len(srv.head_index) == N, (kind, len(srv.head_index))
    assert srv.stats["recoveries"] == 0, (kind, srv.stats)
    print(kind, "PARITY_OK")
print("SERVING_PARITY_OK")
"""


def test_distributed_serving_parity_all_mesh_backends():
    run_on_simulated_mesh(_PARITY_SCRIPT, 8, timeout_base_s=1500,
                          expect="SERVING_PARITY_OK")


# -- (b) deferred-overflow replay when a shard overflows --------------------

_REPLAY_SCRIPT = r"""
import jax, numpy as np
from repro.data import points as gen
from repro.serving.server import SpatialServer

pts = np.asarray(gen.uniform(jax.random.PRNGKey(0), 1024, 2))
# deliberately tight per-shard rows: the unchecked inserts overflow a
# shard's leaf slab, the sticky flag rides the lineage, and the next
# barrier (window eviction / commit) replays from the committed base
srv = SpatialServer.build("spac-h", pts, mesh=mesh, phi=8, window=2,
                          capacity_rows=24)
total = 1024
for r in range(4):
    batch = np.asarray(gen.uniform(jax.random.PRNGKey(10 + r), 512, 2))
    srv.insert(batch)
    total += 512
srv.commit()
assert len(srv.head_index) == total, (len(srv.head_index), total)
assert srv.stats["recoveries"] >= 1, srv.stats
assert int(srv.head_index.dropped) == 0
# post-recovery head serves exact answers
qs = np.asarray(gen.uniform(jax.random.PRNGKey(2), 4, 2))
d2, bp, ok = srv.snapshot().knn(qs, 5)
assert np.asarray(ok).all()
print("REPLAY_OK")
"""


def test_distributed_shard_overflow_replay():
    run_on_simulated_mesh(_REPLAY_SCRIPT, 8, timeout_base_s=1200,
                          expect="REPLAY_OK")


# -- (c) retrace bound across the distributed exchange ----------------------

_TRACE_SCRIPT = r"""
import jax, numpy as np
from repro.core import engine
from repro.data import points as gen
from repro.serving.batcher import MicroBatcher
from repro.serving.server import SpatialServer

pts = np.asarray(gen.uniform(jax.random.PRNGKey(0), 2048, 2))
srv = SpatialServer.build("spac-h", pts, mesh=mesh, phi=8, window=3)
qs = np.asarray(gen.uniform(jax.random.PRNGKey(2), 16, 2))
lo, hi = gen.query_boxes(jax.random.PRNGKey(3), 8, 2, gen.DEFAULT_HI // 8)
lo, hi = np.asarray(lo), np.asarray(hi)
bat = MicroBatcher(max_batch=1024, max_delay_s=60.0)

def round_(r):
    bat.target = srv.snapshot()
    tks = [bat.submit_knn(qs[i], 5) for i in range(16)]
    tks += [bat.submit_range_count(lo[i], hi[i]) for i in range(8)]
    batch = np.asarray(gen.uniform(jax.random.PRNGKey(100 + r), 128, 2))
    srv.insert(batch)
    srv.delete(batch)
    for t in tks:
        t.result()
    srv.commit()

round_(0)   # warm: compiles + pow2 bucket escalations happen here
engine.reset_trace_count()
for r in range(1, 4):
    round_(r)
assert engine.trace_count() == 0, engine.trace_count()
print("TRACE_BOUND_OK")
"""


def test_distributed_retrace_bound():
    run_on_simulated_mesh(_TRACE_SCRIPT, 8, timeout_base_s=1200,
                          expect="TRACE_BOUND_OK")
