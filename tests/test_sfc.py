"""Correctness of Morton/Hilbert encodings against slow bit-level references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sfc


def _morton_ref(coords, bits):
    """Pure-python bit-loop Morton reference."""
    coords = np.asarray(coords)
    dim = coords.shape[-1]
    out = np.zeros(coords.shape[:-1], dtype=np.uint64)
    for b in range(bits):
        for i in range(dim):
            bit = (coords[..., i].astype(np.uint64) >> b) & 1
            out |= bit << np.uint64(b * dim + (dim - 1 - i))
    return out


@pytest.mark.parametrize("dim,bits", [(2, 4), (2, 16), (3, 4), (3, 10)])
def test_morton_matches_reference(dim, bits):
    rng = np.random.default_rng(0)
    pts = rng.integers(0, 2**bits, size=(512, dim), dtype=np.uint32)
    got = np.asarray(sfc.morton_encode(jnp.asarray(pts), bits)).astype(np.uint64)
    want = _morton_ref(pts, bits)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dim,bits", [(2, 3), (2, 5), (3, 3)])
def test_hilbert_roundtrip_and_continuity(dim, bits):
    """Exhaustively decode every index: roundtrip + unit-step continuity.

    Continuity (consecutive Hilbert indexes are Manhattan-distance-1 apart)
    uniquely characterizes a Hilbert-like curve and is the property the paper
    relies on (Sec. 5.1.3: 'adjacent codes are always geometrically close').
    """
    n = 2 ** (dim * bits)
    codes = jnp.arange(n, dtype=jnp.uint32)
    pts = sfc.hilbert_decode(codes, dim, bits)
    # roundtrip
    back = sfc.hilbert_encode(pts, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
    # continuity: each consecutive pair differs by exactly 1 in exactly one dim
    p = np.asarray(pts).astype(np.int64)
    diff = np.abs(np.diff(p, axis=0)).sum(axis=1)
    np.testing.assert_array_equal(diff, np.ones(n - 1, dtype=np.int64))
    # bijectivity onto the full grid
    flat = p[:, 0]
    for i in range(1, dim):
        flat = flat * (2**bits) + p[:, i]
    assert len(np.unique(flat)) == n


@pytest.mark.parametrize("dim,bits", [(2, 16), (3, 10)])
def test_hilbert_locality_beats_morton(dim, bits):
    """Sanity: average |code delta| of spatially-adjacent cells is smaller for
    Hilbert than Morton (the reason SPaC-H queries beat SPaC-Z, Fig. 4)."""
    rng = np.random.default_rng(1)
    pts = rng.integers(0, 2**bits - 1, size=(4096, dim), dtype=np.uint32)
    nbr = pts.copy()
    nbr[:, 0] += 1  # unit step in dim 0
    h0 = np.asarray(sfc.hilbert_encode(jnp.asarray(pts), bits)).astype(np.float64)
    h1 = np.asarray(sfc.hilbert_encode(jnp.asarray(nbr), bits)).astype(np.float64)
    z0 = np.asarray(sfc.morton_encode(jnp.asarray(pts), bits)).astype(np.float64)
    z1 = np.asarray(sfc.morton_encode(jnp.asarray(nbr), bits)).astype(np.float64)
    assert np.median(np.abs(h1 - h0)) <= np.median(np.abs(z1 - z0))


def test_morton_order_is_sorted_along_z_pattern():
    # 2x2 grid: Z order is (0,0),(0,1),(1,0),(1,1) with dim0 as MSB
    pts = jnp.array([[0, 0], [0, 1], [1, 0], [1, 1]], dtype=jnp.uint32)
    codes = np.asarray(sfc.morton_encode(pts, 1))
    np.testing.assert_array_equal(codes, [0, 1, 2, 3])


def test_jit_and_vmap_compatible():
    pts = jnp.arange(24, dtype=jnp.uint32).reshape(12, 2)
    f = jax.jit(lambda p: sfc.hilbert_encode(p, 8))
    np.testing.assert_array_equal(np.asarray(f(pts)),
                                  np.asarray(sfc.hilbert_encode(pts, 8)))
