"""kd / Zd-like baselines answer queries exactly (shared engine)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines
from tests.test_core_indexes import (brute_knn, brute_range_count,
                                     check_queries, gen_points, live_points)


@pytest.mark.parametrize("dist", ["uniform", "varden"])
def test_kd_build_query(dist):
    rng = np.random.default_rng(31)
    pts = gen_points(rng, 1500, 2, dist)
    t = baselines.kd_build(jnp.asarray(pts), phi=8, max_depth=16)
    assert int(t.size) == len(pts)
    np.testing.assert_array_equal(
        np.sort(live_points(t.view()), axis=0), np.sort(pts, axis=0))
    check_queries(t.view(), pts, rng)


def test_kd_insert_delete_rebuild():
    rng = np.random.default_rng(37)
    pts = gen_points(rng, 800, 2, "uniform")
    extra = gen_points(rng, 300, 2, "uniform")
    t = baselines.kd_build(jnp.asarray(pts), phi=8, max_depth=16)
    t = baselines.kd_insert(t, jnp.asarray(extra), max_depth=16,
                            capacity_rows=t.pts.shape[0] * 2)
    assert int(t.size) == 1100
    sel = rng.permutation(800)[:200]
    t = baselines.kd_delete(t, jnp.asarray(pts[sel]), max_depth=16,
                            capacity_rows=t.pts.shape[0])
    assert int(t.size) == 900
    keep = np.concatenate([np.delete(pts, sel, axis=0), extra])
    check_queries(t.view(), keep, rng)


@pytest.mark.parametrize("dist", ["uniform", "sweepline"])
def test_zd_build_query(dist):
    rng = np.random.default_rng(41)
    pts = gen_points(rng, 1500, 2, dist)
    t = baselines.zd_build(jnp.asarray(pts), phi=8)
    assert int(t.size) == len(pts)
    check_queries(t.view(), pts, rng)


def test_zd_kd_leaf_sizes():
    rng = np.random.default_rng(43)
    pts = gen_points(rng, 2000, 2, "uniform")
    kd = baselines.kd_build(jnp.asarray(pts), phi=8, max_depth=16)
    cnt = np.asarray(kd.count)[np.asarray(kd.active)]
    assert cnt.max() <= 8  # kd median splits always reach phi
