"""Per-arch smoke tests: every assigned architecture instantiates a
reduced same-family config, runs one forward + one train step on CPU,
and asserts output shapes + finiteness. Serving consistency (prefill +
decode == teacher-forced forward) is asserted in f32 where exact; MoE
archs additionally need non-dropping capacity (discrete routing flips
under bf16 rounding are expected — see DESIGN.md)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.train.step import TrainCfg, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # full arch sweep exceeds the CI fast tier

ARCHS = list(C.ARCHS)


def _batch(cfg, B=2, S=32, seed=0):
    k = jax.random.PRNGKey(seed)
    b = {}
    if cfg.kind == "encdec":
        b["prefix"] = jax.random.normal(k, (B, S // 2, cfg.frontend_dim))
        b["tokens"] = jax.random.randint(k, (B, S // 2), 0, cfg.vocab,
                                         dtype=jnp.int32)
        b["labels"] = jax.random.randint(k, (B, S // 2), 0, cfg.vocab,
                                         dtype=jnp.int32)
    elif cfg.frontend is not None:
        st = S - cfg.frontend_seq
        b["prefix"] = jax.random.normal(
            k, (B, cfg.frontend_seq, cfg.frontend_dim))
        b["tokens"] = jax.random.randint(k, (B, st), 0, cfg.vocab,
                                         dtype=jnp.int32)
        b["labels"] = jax.random.randint(k, (B, st), 0, cfg.vocab,
                                         dtype=jnp.int32)
    else:
        b["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab,
                                         dtype=jnp.int32)
        b["labels"] = jax.random.randint(k, (B, S), 0, cfg.vocab,
                                         dtype=jnp.int32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = C.smoke(arch)
    tcfg = TrainCfg()
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = _batch(cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert jnp.isfinite(metrics["grad_norm"]), arch
    assert int(opt2["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, arch

    if cfg.kind == "encdec":
        logits = ED.forward(params, batch["prefix"], batch["tokens"], cfg)
        assert logits.shape == (*batch["tokens"].shape, cfg.vocab)
    else:
        logits = T.forward(params, batch["tokens"], cfg,
                           prefix_embed=batch.get("prefix"))
        S_total = batch["tokens"].shape[1] + (
            batch["prefix"].shape[1] if "prefix" in batch else 0)
        assert logits.shape == (2, S_total, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "rwkv6-3b",
                                  "jamba-1.5-large-398b",
                                  "qwen3-moe-235b-a22b", "yi-9b"])
def test_decode_matches_forward(arch):
    """prefill + step-by-step decode reproduces the teacher-forced
    logits (f32; MoE capacity raised so no token drops)."""
    cfg = C.smoke(arch).with_(act_dtype="float32")
    if cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=4.0))
    B, S = 2, 40
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab,
                              dtype=jnp.int32)
    ref = T.forward(params, toks, cfg)
    P = S - 6
    lg, cache = T.prefill(params, toks[:, :P], cfg, max_len=S)
    scale = float(jnp.max(jnp.abs(ref)))
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - ref[:, P - 1])))]
    for i in range(P, S - 1):
        lg, cache = T.decode_step(params, cache, toks[:, i:i + 1], cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - ref[:, i]))))
    assert max(errs) / scale < 1e-4, (arch, errs)


def test_ring_cache_matches_full_attention():
    """Windowed decode with a ring cache == full cache with SWA mask."""
    cfg = C.smoke("h2o-danube-1.8b").with_(act_dtype="float32", window=16)
    B, S = 2, 48   # 3x the window
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab,
                              dtype=jnp.int32)
    ref = T.forward(params, toks, cfg)
    cache = T.init_cache(cfg, B, S)          # ring: W=16 < 48
    assert "pos" in cache and cache["layers"]["pos0"]["k"].shape[3] == 16
    errs = []
    for i in range(S):
        lg, cache = T.decode_step(params, cache, toks[:, i:i + 1], cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - ref[:, i]))))
    assert max(errs) / float(jnp.max(jnp.abs(ref))) < 1e-4, errs


def test_encdec_decode_matches_forward():
    cfg = C.smoke("seamless-m4t-large-v2").with_(act_dtype="float32")
    B, S = 2, 24
    params = ED.init_params(jax.random.PRNGKey(5), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(6),
                               (B, 12, cfg.frontend_dim))
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab,
                              dtype=jnp.int32)
    ref = ED.forward(params, frames, toks, cfg)
    P = S - 5
    lg, cache = ED.prefill(params, frames, toks[:, :P], cfg, max_len=S)
    scale = float(jnp.max(jnp.abs(ref)))
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - ref[:, P - 1])))]
    for i in range(P, S - 1):
        lg, cache = ED.decode_step(params, cache, toks[:, i:i + 1], cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - ref[:, i]))))
    assert max(errs) / scale < 1e-4, errs


def test_causal_prune_matches_unpruned():
    """The triangular kv schedule is numerically identical to the
    rectangular masked scan (the §Perf optimization changes nothing)."""
    cfg = C.smoke("yi-9b").with_(act_dtype="float32")
    B, S = 2, 64
    params = T.init_params(jax.random.PRNGKey(8), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0, cfg.vocab,
                              dtype=jnp.int32)
    a = T.forward(params, toks, cfg.with_(attn_causal_prune=True))
    b = T.forward(params, toks, cfg.with_(attn_causal_prune=False))
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_microbatch_equivalence():
    """n_microbatch=4 produces the same loss and (near-)same grads as a
    single batch (f32 accumulate)."""
    cfg = C.smoke("qwen1.5-0.5b").with_(act_dtype="float32")
    batch = _batch(cfg, B=8, S=32)
    p1, o1 = init_train_state(jax.random.PRNGKey(0), cfg, TrainCfg())
    s1 = jax.jit(make_train_step(cfg, TrainCfg()))
    s4 = jax.jit(make_train_step(cfg, TrainCfg(n_microbatch=4)))
    pa, oa, ma = s1(p1, o1, batch)
    p2, o2 = init_train_state(jax.random.PRNGKey(0), cfg, TrainCfg())
    pb, ob, mb = s4(p2, o2, batch)
    assert abs(float(ma["loss"]) - float(mb["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        assert float(jnp.max(jnp.abs(a - b))) < 2e-3


def test_grad_compression_trains():
    """int8+EF gradient compression still decreases the loss (repeated
    batch: the model must be able to memorize through quantized
    gradients; error feedback carries what int8 rounds away)."""
    from repro.optim.adamw import OptCfg
    cfg = C.smoke("qwen1.5-0.5b").with_(act_dtype="float32")
    tcfg = TrainCfg(compress_grads=True,
                    opt=OptCfg(lr=2e-3, warmup_steps=2, total_steps=20))
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    assert "ef" in opt
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, B=4, S=32, seed=0)
    losses = []
    for i in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert min(losses[-3:]) < losses[0], losses
    # error feedback is actually carrying residuals
    ef_norm = sum(float(jnp.sum(jnp.abs(x)))
                  for x in jax.tree.leaves(opt["ef"]))
    assert ef_norm > 0
