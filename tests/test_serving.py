"""Serving-runtime contract tests (ROADMAP "Serving runtime (PR 3)").

Three guarantees, each asserted bit-for-bit:

* **Snapshot isolation** — queries against version ``v`` return
  identical results while ``v+1``/``v+2``'s update closures are in
  flight on device, for every registered backend.
* **Micro-batcher determinism** — coalesced, pow2-padded answers
  bit-match the answers each request would get dispatched alone.
* **Plan-cache hit rate** — the batcher's pow2 padding keeps a ragged
  request stream inside O(log max_batch) jitted query plans
  (``repro.core.engine.trace_count``), i.e. no per-request retrace.

Plus the deferred-overflow replay (``commit()`` never loses points),
the bounded version window, and a tiny end-to-end driver run.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BACKENDS, engine, make_index
from repro.data import points as gen
from repro.serving import MicroBatcher, SpatialServer
from repro.serving.driver import DriverCfg, run_one

PHI = 8
N, Q, K = 600, 12, 4
HI = 1 << 20

_rng = np.random.default_rng(0)
PTS = _rng.integers(0, HI, size=(N, 2)).astype(np.int32)
QS = _rng.integers(0, HI, size=(Q, 2)).astype(np.int32)
BATCH = _rng.integers(0, HI, size=(128, 2)).astype(np.int32)
BOX_LO = _rng.integers(0, HI // 2, size=(Q, 2)).astype(np.int32)
BOX_HI = BOX_LO + np.int32(HI // 3)


def _server(kind: str, **kw) -> SpatialServer:
    return SpatialServer.build(kind, jnp.asarray(PTS), phi=PHI,
                               capacity_points=2 * N, **kw)


# ---------------------------------------------------------------------------
# snapshot isolation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(BACKENDS))
def test_snapshot_isolation(kind):
    """Queries against version v are bit-identical before and while
    v+1/v+2's updates are in flight; the committed head sees them."""
    srv = _server(kind)
    snap = srv.snapshot()
    d2_a, ids_a = map(np.asarray, snap.knn(QS, K))
    cnt_a = np.asarray(snap.range_count(BOX_LO, BOX_HI))

    srv.insert(jnp.asarray(BATCH))          # v+1 in flight
    srv.delete(jnp.asarray(PTS[:100]))      # v+2 in flight
    assert srv.in_flight >= 1 and srv.head_version == snap.version + 2

    d2_b, ids_b = map(np.asarray, snap.knn(QS, K))
    np.testing.assert_array_equal(d2_a, d2_b)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(
        cnt_a, np.asarray(snap.range_count(BOX_LO, BOX_HI)))

    v = srv.commit()
    assert v == snap.version + 2
    head = srv.snapshot()
    assert len(head) == N + BATCH.shape[0] - 100
    assert len(snap.index) == N           # the old version is untouched


def test_snapshot_of_evicted_version_raises():
    srv = _server("spac-h", window=2)
    v0 = srv.head_version
    for i in range(4):
        srv.insert(jnp.asarray(BATCH[i * 16: (i + 1) * 16]))
    assert len(srv.versions) == 2         # bounded window
    with pytest.raises(KeyError):
        srv.snapshot(v0)
    srv.commit()
    assert srv.versions == (srv.head_version,)


def test_server_rejects_donating_index():
    idx = make_index("spac-h", jnp.asarray(PTS), phi=PHI, donate=True)
    with pytest.raises(ValueError, match="non-donating"):
        SpatialServer(idx)


# ---------------------------------------------------------------------------
# deferred overflow check: commit replays, never loses points
# ---------------------------------------------------------------------------

def test_commit_recovers_deferred_overflow():
    """Async inserts past capacity set the sticky flag; commit replays
    from the last good version through the facade's recovery ladder and
    the committed head holds the exact multiset."""
    idx = make_index("spac-h", jnp.asarray(PTS), phi=PHI)  # tight rows
    srv = SpatialServer(idx, window=3)
    rng = np.random.default_rng(3)
    total = N
    for _ in range(6):
        batch = rng.integers(0, HI, size=(600, 2)).astype(np.int32)
        srv.insert(jnp.asarray(batch))
        total += 600
    srv.commit()
    assert len(srv.head_index) == total
    assert srv.stats["recoveries"] >= 1


# ---------------------------------------------------------------------------
# micro-batcher: bit-parity with per-request dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(BACKENDS))
def test_batcher_bit_parity(kind):
    """Coalesced pow2-padded answers == per-request answers, bitwise,
    for ragged kNN and range requests on every backend."""
    idx = make_index(kind, jnp.asarray(PTS), phi=PHI)
    mb = MicroBatcher(idx, max_batch=1 << 30, max_delay_s=1e9)
    spans = [(0, 1), (1, 4), (4, 9), (9, Q)]     # ragged request sizes
    knn_t = [mb.submit_knn(QS[a:b], K) for a, b in spans]
    rng_t = [mb.submit_range_count(BOX_LO[a:b], BOX_HI[a:b])
             for a, b in spans]
    lst_t = [mb.submit_range_list(BOX_LO[a:b], BOX_HI[a:b])
             for a, b in spans]
    assert mb.pending == 3 * Q
    mb.flush()
    assert mb.pending == 0
    for (a, b), t in zip(spans, knn_t):
        d2, ids = idx.knn(QS[a:b], K)
        got_d2, got_ids = t.result()
        np.testing.assert_array_equal(np.asarray(got_d2), np.asarray(d2))
        np.testing.assert_array_equal(np.asarray(got_ids),
                                      np.asarray(ids))
    for (a, b), t in zip(spans, rng_t):
        want = idx.range_count(BOX_LO[a:b], BOX_HI[a:b])
        np.testing.assert_array_equal(np.asarray(t.result()),
                                      np.asarray(want))
    for (a, b), t in zip(spans, lst_t):
        got_ids, got_cnt = t.result()
        _, want_cnt = idx.range_list(BOX_LO[a:b], BOX_HI[a:b])
        np.testing.assert_array_equal(np.asarray(got_cnt),
                                      np.asarray(want_cnt))
        # padded width may differ between batch and solo runs; the id
        # *sets* per request must not
        got = np.asarray(got_ids)
        assert ((got >= 0).sum(-1) == np.asarray(want_cnt)).all()


def test_batcher_admission_knobs():
    """max_batch triggers a flush on its own; max_delay_s=0 flushes on
    every submit (no coalescing-by-wait)."""
    idx = make_index("spac-h", jnp.asarray(PTS), phi=PHI)
    mb = MicroBatcher(idx, max_batch=4, max_delay_s=1e9)
    ts = [mb.submit_knn(QS[i], K) for i in range(4)]
    assert all(t.done for t in ts)        # size-triggered flush
    mb0 = MicroBatcher(idx, max_batch=1 << 30, max_delay_s=0.0)
    t = mb0.submit_knn(QS[0], K)
    assert t.done                         # delay-triggered flush
    clock = [0.0]
    mb1 = MicroBatcher(idx, max_batch=1 << 30, max_delay_s=1.0,
                       clock=lambda: clock[0])
    tk = mb1.submit_knn(QS[0], K)
    assert not tk.done and mb1.poll() == 0   # deadline not reached
    clock[0] = 2.0
    assert mb1.poll() == 1 and tk.done       # cooperative deadline


def test_batcher_target_reassign_drains_pending():
    """Reassigning target flushes queued requests against the target
    they were submitted to — results are never attributed to the wrong
    version."""
    srv = _server("spac-h")
    snap = srv.snapshot()
    mb = MicroBatcher(snap, max_batch=1 << 30, max_delay_s=1e9)
    t = mb.submit_range_count(np.zeros((1, 2), np.int32),
                              np.full((1, 2), HI - 1, np.int32))
    srv.insert(jnp.asarray(BATCH))
    srv.commit()
    mb.target = srv.snapshot()            # drains against the old snap
    assert t.done
    assert int(np.asarray(t.result())[0]) == N


def test_batcher_snapshot_provider():
    """A callable target resolves at flush time, so one flush answers
    against one consistent version even as the server advances."""
    srv = _server("spac-h")
    mb = MicroBatcher(srv.snapshot, max_batch=1 << 30, max_delay_s=1e9)
    t1 = mb.submit_range_count(np.zeros((1, 2), np.int32),
                               np.full((1, 2), HI - 1, np.int32))
    srv.insert(jnp.asarray(BATCH))
    srv.commit()
    # flush happens now: answers come from the post-commit head
    assert int(np.asarray(t1.result())[0]) == N + BATCH.shape[0]


# ---------------------------------------------------------------------------
# pow2 padding keeps ragged streams on cached query plans
# ---------------------------------------------------------------------------

def test_batcher_pow2_padding_hits_cached_plans():
    """A ragged stream of request sizes compiles one plan per pow2
    bucket (not per size), and a replay of the same stream compiles
    nothing — the trace-counter bound for the serving path."""
    idx = make_index("spac-h", jnp.asarray(PTS), phi=PHI)
    mb = MicroBatcher(idx, max_batch=1 << 30, max_delay_s=1e9)
    sizes = [1, 2, 3, 5, 7, 9, 12]
    buckets = {1 << max(s - 1, 0).bit_length() for s in sizes}

    engine._knn_closure.cache_clear()
    engine.reset_trace_count()
    for s in sizes:
        mb.submit_knn(QS[:s], K)
        mb.flush()                        # one padded call per size
    assert engine.trace_count() == len(buckets), \
        (engine.trace_count(), buckets)
    for s in sizes:                       # steady state: zero retrace
        mb.submit_knn(QS[:s], K)
        mb.flush()
    assert engine.trace_count() == len(buckets)


# ---------------------------------------------------------------------------
# traces + driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", gen.SCENARIOS)
def test_traces_deterministic(scenario):
    a = gen.make_trace(scenario, seed=4, n=300, batch=32, steps=3)
    b = gen.make_trace(scenario, seed=4, n=300, batch=32, steps=3)
    assert a.max_live == b.max_live
    np.testing.assert_array_equal(np.asarray(a.bootstrap),
                                  np.asarray(b.bootstrap))
    for sa, sb in zip(a.steps, b.steps):
        np.testing.assert_array_equal(np.asarray(sa.insert),
                                      np.asarray(sb.insert))
        np.testing.assert_array_equal(np.asarray(sa.delete),
                                      np.asarray(sb.delete))


def test_churn_deletes_land():
    """Churn steps retire a quarter of the *previous* batch — points
    that exist when the (delete-before-insert) step applies, so the
    replayed live count matches Trace.max_live bookkeeping (regression:
    deleting from the step's own not-yet-inserted batch no-op'd every
    delete)."""
    n, batch, steps = 300, 32, 3
    tr = gen.make_trace("uniform", n=n, batch=batch, steps=steps)
    idx = make_index("spac-h", tr.bootstrap, phi=PHI,
                     capacity_points=tr.max_live)
    for step in tr.steps:
        idx = idx.delete(step.delete).insert(step.insert)
    assert len(idx) == n + steps * (batch - batch // 4) == tr.max_live


def test_moving_objects_conserves_size():
    """moving-objects deletes exactly what it displaces: replaying the
    trace keeps the live count at n."""
    tr = gen.make_trace("moving-objects", n=300, batch=64, steps=3)
    assert tr.max_live == 300
    idx = make_index("spac-h", tr.bootstrap, phi=PHI)
    for step in tr.steps:
        idx = idx.delete(step.delete).insert(step.insert)
    assert len(idx) == 300


def test_driver_end_to_end_tiny():
    """run_one reports every op's percentiles and the sliding window
    holds the live set constant."""
    cfg = DriverCfg(n=400, batch=64, steps=2, warmup=1, queries=8, k=4)
    out = run_one("spac-h", "sliding-window", cfg)
    lat = out["latency_ms"]
    for op in ("insert", "delete", "knn", "range", "commit"):
        assert lat[op]["count"] > 0, op
        assert {"p50_ms", "p95_ms", "p99_ms"} <= set(lat[op]), op
    assert out["final_size"] == 400
    assert out["recoveries"] == 0
    assert out["throughput"]["query_per_s"] > 0
