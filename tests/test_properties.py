"""Hypothesis property tests for the index invariants (DESIGN.md Sec. 8).

Shapes are held constant (n=96 points, masked) so jit caches across
examples; hypothesis varies coordinates — including tiny ranges that
force heavy duplicates, the regime that broke routed deletion before
the banded fix."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis dev extra")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.extra import numpy as hnp  # noqa: E402

from repro.core import porth, queries, spac

N, M, K = 96, 32, 5
HI = 1 << 12
ROOT_LO = jnp.zeros((2,), jnp.int32)
ROOT_HI = jnp.full((2,), HI, jnp.int32)

coords = st.one_of(
    hnp.arrays(np.int32, (N, 2), elements=st.integers(0, HI - 1)),
    hnp.arrays(np.int32, (N, 2), elements=st.integers(0, 7)),   # dupes
    hnp.arrays(np.int32, (N, 2), elements=st.integers(100, 110)),
)
batch = hnp.arrays(np.int32, (M, 2), elements=st.integers(0, HI - 1))

SET = settings(max_examples=12, deadline=None)


def brute_knn(pts_ok, q, k):
    pts, ok = pts_ok
    d2 = np.sum((pts.astype(np.float64) - q.astype(np.float64)) ** 2, -1)
    d2 = np.where(ok, d2, np.inf)
    return np.sort(d2)[:k]


def tree_points(view):
    ok = np.asarray(view.valid & view.active[:, None]).reshape(-1)
    pts = np.asarray(view.pts).reshape(-1, 2)
    return pts, ok


def _build_spac(pts, mask=None):
    return spac.build(jnp.asarray(pts), mask, phi=8, bits=12,
                      coord_bits=12, capacity_rows=256)


def _build_porth(pts, mask=None):
    return porth.build(jnp.asarray(pts), ROOT_LO, ROOT_HI, mask, phi=8,
                       lam=2, rounds=6, capacity_rows=512)


@SET
@given(coords, batch)
def test_spac_knn_exact_after_updates(pts, upd):
    t = _build_spac(pts)
    t = spac.insert(t, jnp.asarray(upd))
    t = spac.delete(t, jnp.asarray(pts[: N // 3]))
    assert not bool(t.overflowed)
    view = t.view()
    tp = tree_points(view)
    # multiset size invariant
    assert tp[1].sum() == N + M - N // 3
    qs = jnp.asarray(np.vstack([upd[:4], pts[:4]]))
    d2, ids = queries.knn(view, qs, K)
    for i in range(qs.shape[0]):
        bf = brute_knn(tp, np.asarray(qs[i]), K)
        got = np.sort(np.asarray(d2[i], np.float64))
        np.testing.assert_allclose(got[: len(bf)], bf, rtol=1e-6)


@SET
@given(coords, batch)
def test_porth_knn_exact_after_updates(pts, upd):
    t = _build_porth(pts)
    t = porth.insert(t, jnp.asarray(upd))
    t = porth.delete(t, jnp.asarray(pts[: N // 3]))
    assert not bool(t.overflowed)
    view = t.view()
    tp = tree_points(view)
    assert tp[1].sum() == N + M - N // 3
    qs = jnp.asarray(upd[:6])
    d2, _ = queries.knn(view, qs, K)
    for i in range(qs.shape[0]):
        bf = brute_knn(tp, np.asarray(qs[i]), K)
        np.testing.assert_allclose(
            np.sort(np.asarray(d2[i], np.float64))[: len(bf)], bf,
            rtol=1e-6)


@SET
@given(coords, batch)
def test_insert_equals_bulk_build(pts, upd):
    """insert(build(P), Q) answers queries exactly like build(P u Q)."""
    t1 = spac.insert(_build_spac(pts), jnp.asarray(upd))
    allp = np.vstack([pts, upd])
    t2 = _build_spac(allp)
    qs = jnp.asarray(upd[:6])
    d1, _ = queries.knn(t1.view(), qs, K)
    d2_, _ = queries.knn(t2.view(), qs, K)
    np.testing.assert_allclose(np.sort(np.asarray(d1), axis=1),
                               np.sort(np.asarray(d2_), axis=1), rtol=1e-6)


@SET
@given(coords)
def test_delete_restores_build_answers(pts):
    """build(P) -> insert(Q) -> delete(Q) answers like build(P)."""
    q = (pts[: M] + 17) % HI
    t = _build_spac(pts)
    t = spac.insert(t, jnp.asarray(q))
    t = spac.delete(t, jnp.asarray(q))
    tp = tree_points(t.view())
    assert tp[1].sum() == N
    ref = _build_spac(pts)
    qs = jnp.asarray(pts[:6])
    d1, _ = queries.knn(t.view(), qs, K)
    d2_, _ = queries.knn(ref.view(), qs, K)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2_), rtol=1e-6)


@SET
@given(coords)
def test_spac_structural_invariants(pts):
    t = spac.insert(_build_spac(pts), jnp.asarray(pts[:M]))
    valid = np.asarray(t.valid)
    count = np.asarray(t.count)
    active = np.asarray(t.active)
    # occupancy: count == number of valid slots, within capacity
    np.testing.assert_array_equal(valid.sum(1)[active], count[active])
    assert (count <= t.row_capacity).all()
    # bbox tightness: every valid point inside its row bbox
    p = np.asarray(t.pts)
    lo = np.asarray(t.bbox_lo)[:, None]
    hi = np.asarray(t.bbox_hi)[:, None]
    ok = valid & active[:, None]
    assert ((p >= lo) | ~ok[..., None]).all()
    assert ((p <= hi) | ~ok[..., None]).all()
    # directory: active rows sorted by min_code
    order = np.asarray(t.order)
    mc = np.asarray(t.min_code)[order]
    nr = int(t.num_rows)
    assert (np.diff(mc[:nr].astype(np.int64)) >= 0).all()
    # codes stored == recomputed encode(points)
    codes = np.asarray(t.codes)
    ref = np.asarray(spac._encode(jnp.asarray(p.reshape(-1, 2)), t.curve,
                                  t.bits, t.coord_bits)).reshape(codes.shape)
    np.testing.assert_array_equal(codes[ok], ref[ok])


@SET
@given(coords)
def test_range_count_exact(pts):
    t = _build_spac(pts)
    lo = jnp.asarray([[0, 0], [10, 10], [0, 2000]], jnp.int32)
    hi = jnp.asarray([[HI, HI], [200, 220], [3000, 2100]], jnp.int32)
    cnt, trunc = queries.range_count(t.view(), lo, hi, max_rows=256)
    assert not bool(trunc.any())
    for i in range(3):
        bf = int(np.sum(np.all((pts >= np.asarray(lo[i]))
                               & (pts <= np.asarray(hi[i])), -1)))
        assert int(cnt[i]) == bf


@SET
@given(coords)
def test_porth_history_independence(pts):
    """Orth-trees are history-independent *modulo leaf wrapping* (paper
    Sec. 5.1.3): different insertion orders may wrap/merge underfull
    sibling cells differently, but the point multiset and every query
    answer must be order-independent. Structural statistics agree up to
    leaf-wrap: total size and occupied-cell count within merge slack."""
    a, b = pts[: N // 2], pts[N // 2:]
    t1 = porth.insert(_build_porth(a), jnp.asarray(b))
    t2 = porth.insert(_build_porth(b), jnp.asarray(a))
    s1 = int(np.asarray(t1.count)[np.asarray(t1.active)].sum())
    s2 = int(np.asarray(t2.count)[np.asarray(t2.active)].sum())
    assert s1 == s2 == N
    qs = jnp.asarray(pts[:8])
    d1, _ = queries.knn(t1.view(), qs, K)
    d2_, _ = queries.knn(t2.view(), qs, K)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2_), rtol=1e-6)
