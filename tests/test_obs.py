"""repro.obs: recorder semantics, counter parity with the engine's
trace accounting, batcher coalesce/pad counters, deferred device-read
resolution, exporter round-trips, and the disabled-mode overhead bound.

The parity tests pin the tentpole claim: the obs counters are *the
same events* the library already counts internally (engine traces,
plan-cache misses, escalation rounds), not a parallel estimate — so a
trace-count assertion and an obs-counter assertion can never drift.
"""

from __future__ import annotations

import json
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import engine, make_index
from repro.obs import view
from repro.serving import LatencyRecorder, MicroBatcher, SpatialServer


@pytest.fixture(autouse=True)
def _obs_disabled():
    """Every test starts and ends with no recorder installed."""
    obs.uninstall()
    yield
    obs.uninstall()


def _pts(n, dim=2, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 100.0, size=(n, dim)).astype(np.float32)


# -- recorder core ----------------------------------------------------------

def test_pow2_bucket():
    assert obs.pow2_bucket(0) == 0.0
    assert obs.pow2_bucket(-3.0) == 0.0
    assert obs.pow2_bucket(1.0) == 1.0
    assert obs.pow2_bucket(3.0) == 4.0
    assert obs.pow2_bucket(4.0) == 4.0
    assert obs.pow2_bucket(0.75) == 1.0


def test_hist_summary_exact_until_retention():
    h = obs.Hist(max_samples=4)
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["min"] == 1.0 and s["max"] == 4.0
    assert s["p50"] == 2.0 and s["p99"] == 4.0
    h.observe(100.0)                      # past retention: bucket edges
    assert h.dropped == 1
    assert h.summary()["count"] == 5
    assert h.summary()["max"] == 100.0


def test_span_timing_uses_recorder_clock():
    now = [0.0]
    rec = obs.Recorder(clock=lambda: now[0])
    with rec.span("step", cat="test", kind="unit") as sp:
        now[0] = 1.5
        sp.set(rows=7)
    (ev,) = rec.events
    assert ev["name"] == "step" and ev["cat"] == "test"
    assert ev["ts"] == 0.0 and ev["dur"] == 1.5
    assert ev["args"] == {"kind": "unit", "rows": 7}
    rec.add_span("ext", 2.0, 0.5)
    assert rec.events[-1] == {"name": "ext", "ts": 2.0, "dur": 0.5}


def test_module_helpers_route_to_installed_recorder():
    rec = obs.Recorder()
    with obs.recording(rec) as r:
        assert r is rec and obs.enabled() and obs.recorder() is rec
        obs.count("c")
        obs.count("c", 2)
        obs.gauge("g", 5)
        obs.gauge("g", 3)
        obs.observe("h", 8.0)
    assert not obs.enabled()
    assert rec.counters["c"] == 3
    assert rec.gauges["g"] == {"value": 3, "max": 5, "n": 2}
    assert rec.hist("h").count == 1


# -- deferred device reads --------------------------------------------------

def test_deferred_values_resolve_only_at_barrier():
    rec = obs.Recorder()
    with obs.recording(rec):
        total = jnp.asarray([1, 2, 3]).sum()     # in-flight device value
        with obs.span("work") as sp:
            sp.defer("total", total)
        obs.defer("points", jnp.asarray(5))
        assert rec.pending == 2
        # the span already ended; its deferred slot is a placeholder
        assert rec.events[-1]["args"]["total"] is None
        assert obs.resolve() == 2
        assert rec.pending == 0
    assert rec.events[-1]["args"]["total"] == 6.0
    assert "total_resolved_s" in rec.events[-1]["args"]
    assert rec.counters["points"] == 5.0


def test_server_commit_is_the_obs_barrier():
    pts = _pts(256)
    with obs.recording() as rec:
        srv = SpatialServer.build("porth", pts, capacity_points=1024)
        with obs.span("ingest") as sp:
            srv.insert(_pts(32, seed=1))
            sp.defer("live", jnp.asarray(288))
        assert rec.pending == 1
        srv.commit()                     # commit drains deferred reads
        assert rec.pending == 0
    names = [ev["name"] for ev in rec.events]
    assert "serving.insert" in names and "serving.commit" in names


# -- parity with the library's own accounting -------------------------------

def test_engine_trace_counter_parity():
    """obs ``engine.trace`` increments next to ``_STATS["traces"]``
    inside the jitted closures, so over any recording window the obs
    delta equals the ``engine.trace_count()`` delta exactly."""
    pts = _pts(300, seed=2)
    with obs.recording() as rec:
        idx = make_index("porth", pts)
        t0 = engine.trace_count()
        c0 = rec.counters.get("engine.trace", 0)
        q = _pts(13, seed=3)             # 13 rows: a fresh plan signature
        d2a, _ = idx.knn(q, 3)
        d2b, _ = idx.knn(q, 3)           # cached plan: no new trace
        t_delta = engine.trace_count() - t0
        o_delta = rec.counters.get("engine.trace", 0) - c0
    assert t_delta >= 1
    assert o_delta == t_delta
    assert rec.counters["engine.plan_request"] >= 2
    assert rec.counters.get("engine.plan_miss", 0) >= 1
    assert sum(v for k, v in rec.counters.items()
               if k.startswith("engine.route.")) \
        == rec.counters["engine.plan_request"]
    np.testing.assert_array_equal(np.asarray(d2a), np.asarray(d2b))


def test_escalation_counter_matches_rounds_histogram():
    """``engine.escalation`` (one per extra round) must equal the sum
    of the per-call ``engine.escalation_rounds`` observations."""
    pts = _pts(2048, seed=4)
    with obs.recording() as rec:
        idx = make_index("porth", pts)
        lo = np.zeros((4, 2), dtype=np.float32)
        hi = np.full((4, 2), 100.0, dtype=np.float32)  # whole domain
        cnt = idx.range_count(lo, hi)
        idx.range_count(lo, hi)          # converged bucket: 0 rounds
    assert int(np.asarray(cnt)[0]) == 2048
    h = rec.hist("engine.escalation_rounds")
    assert h is not None and h.count == 2
    assert rec.counters.get("engine.escalation", 0) == int(h.total)


# -- batcher counters -------------------------------------------------------

def test_batcher_coalesce_pad_and_flush_reasons():
    pts = _pts(256, seed=5)
    idx = make_index("porth", pts)
    with obs.recording() as rec:
        mb = MicroBatcher(idx, max_batch=1024, max_delay_s=10.0)
        tickets = [mb.submit_knn(_pts(1, seed=10 + i)[0], 3)
                   for i in range(5)]
        assert rec.gauges["batcher.queue_depth"]["value"] == 5
        mb.flush()
        [t.result() for t in tickets]
        assert rec.counters["batcher.flush.explicit"] == 1
        assert rec.counters["batcher.requests"] == 5
        assert rec.hist("batcher.coalesce_rows").samples == [5.0]
        # pow2 padding: 5 rows pad to 8, so 3 wasted rows
        assert rec.hist("batcher.pad_rows").samples == [3.0]
        assert rec.hist("batcher.wait_s").count == 5
        # result-forced flush
        t = mb.submit_knn(_pts(1, seed=20)[0], 3)
        t.result()
        assert rec.counters["batcher.flush.result"] == 1
        # size-forced flush
        mb.max_batch = 2
        mb.submit_knn(_pts(2, seed=21), 3).result()
        assert rec.counters["batcher.flush.size"] == 1


# -- LatencyRecorder on obs histograms --------------------------------------

def test_latency_recorder_is_backed_by_obs_hists():
    rec = obs.Recorder()
    lr = LatencyRecorder(recorder=rec)
    lr.record("knn", 0.004, 16, start=rec.clock())
    lr.record("knn", 0.002, 16)
    assert rec.hist("lat.knn").count == 2
    s = lr.latency_summary()["knn"]
    assert s["count"] == 2
    assert s["min_ms"] == pytest.approx(2.0)
    assert s["max_ms"] == pytest.approx(4.0)
    assert lr.count("knn") == 32
    assert rec.events[-1]["name"] == "lat.knn"   # timeline span via start=
    lr.reset()                                   # drops lat.* hists only
    assert lr.latency_summary() == {}
    assert rec.events, "reset must not erase the timeline"


def test_latency_recorder_private_when_no_recorder():
    lr = LatencyRecorder()
    with lr.timer("op"):
        pass
    assert lr.latency_summary()["op"]["count"] == 1
    assert not obs.enabled()


# -- exporters and the view CLI ---------------------------------------------

def test_exporters_roundtrip_and_view_cli(tmp_path, capsys):
    rec = obs.Recorder()
    with obs.recording(rec):
        with obs.span("a", cat="x", n=1):
            pass
        obs.count("c", 2)
        obs.gauge("g", 3)
        obs.observe("h", 4.0)
    chrome = tmp_path / "trace.json"
    lines = tmp_path / "trace.jsonl"
    obs.write_chrome_trace(rec, str(chrome))
    obs.write_jsonl(rec, str(lines))

    data = json.loads(chrome.read_text())
    (ev,) = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert ev["name"] == "a" and ev["dur"] >= 0      # microseconds
    assert data["otherData"]["counters"]["c"] == 2
    recs = [json.loads(ln) for ln in lines.read_text().splitlines()]
    assert recs[0]["type"] == "meta"
    kinds = {r["type"] for r in recs}
    assert {"span", "counter", "gauge", "hist"} <= kinds

    for path in (chrome, lines):
        assert view.main([str(path)]) == 0
        out = capsys.readouterr().out
        assert "a" in out and "c" in out
    assert view.main([str(tmp_path / "missing.json")]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text('{"nope": 1}')
    assert view.main([str(bad)]) == 1
    capsys.readouterr()


# -- disabled mode ----------------------------------------------------------

def test_disabled_mode_is_near_free():
    assert not obs.enabled()
    assert obs.span("x") is obs.NULL_SPAN
    with obs.span("x", a=1) as sp:
        assert sp is obs.NULL_SPAN
        assert sp.set(a=2) is sp
        assert sp.defer("k", object()) is sp
        assert sp.done
    assert obs.resolve() == 0
    # each disabled helper is one dict-slot check; even a slow 1-core
    # CI box does 300k of them in well under the bound
    t0 = time.perf_counter()
    for _ in range(100_000):
        obs.count("c")
        obs.observe("h", 1.0)
        obs.gauge("g", 1)
    assert time.perf_counter() - t0 < 2.0


def test_disabled_mode_records_nothing():
    pts = _pts(128, seed=6)
    idx = make_index("porth", pts)
    idx.knn(_pts(4, seed=7), 3)          # instrumented paths, obs off
    rec = obs.Recorder()
    with obs.recording(rec):
        pass
    assert not rec.counters and not rec.events


# -- thread safety ----------------------------------------------------------

def test_concurrent_increments_are_exact():
    # the batcher's worker threads and the main thread share one
    # recorder; lost updates would silently undercount
    import threading
    rec = obs.Recorder()
    n_threads, n_iter = 8, 2_000

    def work():
        for _ in range(n_iter):
            rec.count("c")
            rec.count("weighted", 2)
            rec.observe("h", 1.0)
            rec.gauge("g", 1)

    with obs.recording(rec):
        threads = [threading.Thread(target=work)
                   for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    total = n_threads * n_iter
    assert rec.counters["c"] == total
    assert rec.counters["weighted"] == 2 * total
    assert rec.hists["h"].count == total


# -- memory accounting ------------------------------------------------------

@pytest.mark.parametrize("kind", ["porth", "spac-h", "kd"])
def test_index_nbytes_matches_leaf_sum(kind):
    import jax
    idx = make_index(kind, _pts(256, seed=11))
    expect = sum(leaf.nbytes
                 for leaf in jax.tree_util.tree_leaves(idx.tree)
                 if hasattr(leaf, "nbytes"))
    assert idx.nbytes == expect > 0
    assert obs.tree_bytes(idx.tree) == expect


def test_server_memory_accounting_tracks_versions():
    srv = SpatialServer.build("spac-h", _pts(256, seed=12),
                              capacity_points=2_048, window=2)
    base = srv.memory_report()
    assert base["live_bytes"] == srv.head_index.nbytes
    assert base["window_bytes"] == base["live_bytes"]
    assert base["evictions"] == 0

    srv.insert(_pts(64, seed=13))            # retained: v0 + v1
    two = srv.memory_report()
    assert two["retained"] == 2
    assert two["window_bytes"] == sum(two["version_bytes"].values())
    assert two["window_bytes"] > two["live_bytes"]

    srv.insert(_pts(64, seed=14))            # evicts v0 (window=2)
    three = srv.memory_report()
    assert three["retained"] == 2
    assert three["evictions"] == 1
    # eviction reclaimed exactly v0's recorded bytes and the window
    # total still equals the per-version ledger
    v0 = min(two["version_bytes"])
    assert three["evicted_bytes"] == two["version_bytes"][v0]
    assert v0 not in three["version_bytes"]
    assert three["window_bytes"] == sum(three["version_bytes"].values())
    assert three["window_bytes"] < \
        two["window_bytes"] + max(three["version_bytes"].values())
    assert three["peak_window_bytes"] >= three["window_bytes"]

    srv.commit()                             # window collapses to head
    done = srv.memory_report()
    assert done["retained"] == 1
    assert done["window_bytes"] == done["live_bytes"]
    assert done["live_bytes"] == srv.head_index.nbytes


def test_server_memory_gauges_only_when_enabled():
    pts, batch = _pts(256, seed=15), _pts(64, seed=16)
    rec = obs.Recorder()
    with obs.recording(rec):
        srv = SpatialServer.build("spac-h", pts, capacity_points=1_024,
                                  window=2)
        srv.insert(batch)
        srv.commit()
    assert rec.gauges["server.mem.live_bytes"]["value"] == \
        srv.head_index.nbytes
    assert "server.mem.window_bytes" in rec.gauges

    srv2 = SpatialServer.build("spac-h", pts, capacity_points=1_024,
                               window=2)
    srv2.insert(batch)
    srv2.commit()                            # obs off: no recorder
    assert srv2.memory_report()["live_bytes"] == srv2.head_index.nbytes
    rec2 = obs.Recorder()
    with obs.recording(rec2):
        pass
    assert "server.mem.live_bytes" not in rec2.gauges


def test_memory_snapshots_only_in_resolve():
    # CPU devices report no allocator stats — the snapshot must be a
    # silent no-op there, and only run at the resolve barrier
    rec = obs.Recorder(memory_snapshots=True)
    with obs.recording(rec):
        obs.count("x")
    rec.resolve()
    backend = [k for k in rec.gauges if k.startswith("backend.mem.")]
    import jax
    has_stats = False
    for dev in jax.local_devices():
        try:
            has_stats = bool(dev.memory_stats())
        except Exception:
            pass
    assert bool(backend) == has_stats


# -- compile-cost capture ---------------------------------------------------

def test_cost_capture_records_each_plan_once():
    pts, qpts = _pts(256, seed=17), _pts(8, seed=18)
    rec = obs.Recorder(capture_costs=True)
    with obs.recording(rec):
        idx = make_index("spac-h", pts)
        idx = idx.insert(_pts(16, seed=21))  # update closure: _run_update
        idx.knn(qpts, 3)
        idx.knn(qpts, 3)                     # same plan: no re-capture
    sigs = obs.costs.plan_costs(rec.counters)
    knn_sigs = [s for s in sigs if s.startswith("knn.")]
    assert len(knn_sigs) >= 1
    for s in knn_sigs:
        assert sigs[s]["bytes"] > 0          # HLO moves real traffic
    update_sigs = [s for s in sigs if s.startswith("update.spac-h.insert")]
    assert update_sigs                       # the insert closure
    assert rec.counters["plan.cost.captured"] == len(sigs)


def test_cost_capture_off_by_default():
    pts, qpts = _pts(256, seed=19), _pts(8, seed=20)
    rec = obs.Recorder()
    with obs.recording(rec):
        idx = make_index("spac-h", pts)
        idx.knn(qpts, 3)
    assert not [k for k in rec.counters if k.startswith("plan.cost.")]


# -- view --by-name ---------------------------------------------------------

def test_view_by_name_aggregation(tmp_path, capsys):
    rec = obs.Recorder()
    with obs.recording(rec):
        for _ in range(3):
            with obs.span("op.alpha", cat="q"):
                pass
        with obs.span("op.beta"):
            pass
    chrome = tmp_path / "t.json"
    lines = tmp_path / "t.jsonl"
    obs.write_chrome_trace(rec, str(chrome))
    obs.write_jsonl(rec, str(lines))
    for path in (chrome, lines):
        report = view.load(str(path))
        agg = view.by_name(report["events"])
        assert agg["op.alpha"]["count"] == 3
        assert agg["op.beta"]["count"] == 1
        assert agg["op.alpha"]["total_ms"] >= agg["op.alpha"]["mean_ms"]
        assert view.main([str(path), "--by-name", "--top", "1"]) == 0
        out = capsys.readouterr().out
        assert "op.alpha" in out and "op.beta" not in out   # top-1
