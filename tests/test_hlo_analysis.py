"""HLO static-analyzer validation: hand-counted FLOPs/collectives on
small programs (single device — loop trip-count multiplication is the
property under test) plus a canned partitioned-HLO snippet for the
collective parser."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def _analyze(fn, *args):
    return H.analyze_text(jax.jit(fn).lower(*args).compile().as_text())


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    res = _analyze(lambda x, y: x @ y, a, b)
    assert res["flops"] == 2 * 64 * 32 * 128


def test_scan_multiplies_body_flops():
    w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def fn(w, x):
        def body(h, wi):
            return h @ wi, None
        return jax.lax.scan(body, x, w)[0]

    res = _analyze(fn, w, x)
    assert res["flops"] == 7 * 2 * 8 * 64 * 64


def test_nested_scan_multiplies_through():
    w = jax.ShapeDtypeStruct((3, 5, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

    def fn(w, x):
        def outer(h, wo):
            def inner(h2, wi):
                return h2 @ wi, None
            return jax.lax.scan(inner, h, wo)[0], None
        return jax.lax.scan(outer, x, w)[0]

    res = _analyze(fn, w, x)
    assert res["flops"] == 3 * 5 * 2 * 4 * 32 * 32


def test_collective_parse_from_canned_hlo():
    hlo = """
HloModule test

%region_b (p: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %p = (s32[], f32[16,16]) parameter(0)
  %g = s32[] get-tuple-element(%p), index=0
  %h = f32[16,16]{1,0} get-tuple-element(%p), index=1
  %ag = f32[16,64]{1,0} all-gather(%h), channel_id=1, dimensions={1}
  %c1 = s32[] constant(1)
  %a = s32[] add(%g, %c1)
  ROOT %t = (s32[], f32[16,16]) tuple(%a, %h)
}

%region_c (p2: (s32[], f32[16,16])) -> pred[] {
  %p2 = (s32[], f32[16,16]) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[16,16]) -> f32[16,16] {
  %x = f32[16,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[16,16]) tuple(%z, %x)
  %w = (s32[], f32[16,16]) while(%tup), condition=%region_c, body=%region_b
  %out = f32[16,16]{1,0} get-tuple-element(%w), index=1
  ROOT %ar = f32[16,16]{1,0} all-reduce(%out), channel_id=2, to_apply=%region_b
}
"""
    res = H.analyze_text(hlo)
    # in-loop all-gather operand: 16*16*4 bytes x 12 trips
    assert res["all-gather"] == 16 * 16 * 4 * 12
    assert res["all-reduce"] == 16 * 16 * 4
    assert res["collective_bytes"] == 16 * 16 * 4 * 13


def test_shape_bytes():
    assert H.shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert H.shape_bytes("(f32[4], s8[16])") == 16 + 16
    assert H.shape_bytes("pred[]") == 1
