"""Checkpoint + fault-tolerance integration tests.

Covers the 1000-node survival story at test scale: atomic saves, resume
determinism (bitwise-equal to an uninterrupted run, thanks to the
(seed, step) data pipeline), elastic restore onto a different mesh
shape, straggler/heartbeat policies, and snapshot rollback."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro import ckpt
from repro.data.tokens import lm_batch
from repro.ft import (FaultTolerantLoop, HeartbeatMonitor, Snapshotter,
                      StragglerTracker)
from repro.train.step import TrainCfg, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # training loops exceed the CI fast tier

CFG = C.smoke("qwen1.5-0.5b").with_(act_dtype="float32")


def _run(steps, start_params, start_opt, step_fn, seed=0, from_step=0):
    params, opt = start_params, start_opt
    for s in range(from_step, steps):
        toks, labels = lm_batch(seed, s, 4, 32, CFG.vocab)
        params, opt, m = step_fn(params, opt,
                                 {"tokens": toks, "labels": labels})
    return params, opt, float(m["loss"])


def test_save_restore_roundtrip(tmp_path):
    tcfg = TrainCfg()
    params, opt = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    ckpt.save({"params": params, "opt": opt}, str(tmp_path), step=7)
    tmpl = {"params": params, "opt": opt}
    (state, step) = ckpt.restore(tmpl, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_is_bitwise_deterministic(tmp_path):
    """Interrupt at step 5 of 10, restore, finish: identical params to an
    uninterrupted 10-step run."""
    tcfg = TrainCfg()
    step_fn = jax.jit(make_train_step(CFG, tcfg))
    p0, o0 = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)

    pa, oa, _ = _run(10, p0, o0, step_fn)                  # straight run

    pb, ob, _ = _run(5, p0, o0, step_fn)                   # interrupted
    ckpt.save({"params": pb, "opt": ob}, str(tmp_path), step=5)
    (state, s) = ckpt.restore({"params": pb, "opt": ob}, str(tmp_path))
    pc, oc, _ = _run(10, state["params"], state["opt"], step_fn,
                     from_step=s)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_atomic(tmp_path):
    tcfg = TrainCfg()
    params, opt = init_train_state(jax.random.PRNGKey(1), CFG, tcfg)
    ckpt.async_save({"params": params}, str(tmp_path), step=3)
    ckpt.wait_pending()
    path, manifest = ckpt.load_manifest(str(tmp_path))
    assert manifest["step"] == 3
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_elastic_reshard(tmp_path):
    """Save from one sharding layout, restore onto another (the lose-a-pod
    / grow-a-pod path). Values must be identical."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    arr = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    ckpt.save({"w": arr}, str(tmp_path), step=1)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    (state, _) = ckpt.restore({"w": arr}, str(tmp_path), shardings=sh)
    np.testing.assert_array_equal(np.asarray(state["w"]), np.asarray(arr))
    assert state["w"].sharding == sh["w"]


def test_heartbeat_monitor():
    clock = [0.0]
    mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout=10,
                           clock=lambda: clock[0])
    clock[0] = 5.0
    mon.beat("h0")
    mon.beat("h1")
    clock[0] = 12.0
    assert mon.dead_hosts() == ["h2"]
    mon.beat("h2")
    assert mon.dead_hosts() == []


def test_straggler_tracker():
    tr = StragglerTracker(k=3.0, patience=2)
    for step in range(6):
        for h in ("h0", "h1", "h2", "h3"):
            tr.record(h, 1.0 + 0.01 * step)
        tr.record("slow", 9.0)
        out = tr.stragglers()
    assert out == ["slow"]


def test_snapshot_rollback():
    snap = Snapshotter(keep=2)
    state = {"w": jnp.ones((4,))}
    snap.snap(3, state)
    step, restored = snap.rollback()
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.ones((4,)))


def test_ft_loop_retries_and_completes(tmp_path):
    """A transient RuntimeError at step 2 is retried and training
    completes with a checkpoint on disk."""
    tcfg = TrainCfg()
    step_fn = jax.jit(make_train_step(CFG, tcfg))
    params, opt = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    loop = FaultTolerantLoop(step_fn, ckpt_dir=str(tmp_path),
                             ckpt_every=4, snap_every=2, max_retries=2)
    fails = {"left": 1}

    def flaky(step):
        if step == 2 and fails["left"]:
            fails["left"] -= 1
            raise RuntimeError("simulated preemption")

    def batches():
        for s in range(6):
            t, l = lm_batch(0, s, 4, 32, CFG.vocab)
            yield s, {"tokens": t, "labels": l}

    params, opt = loop.run((params, opt), batches(), fail_hook=flaky)
    assert loop.retries == 1
    _, manifest = ckpt.load_manifest(str(tmp_path))
    assert manifest["step"] in (0, 4)
